//! Minimal benchmarking harness (criterion substitute, offline sandbox).
//!
//! Benches under `rust/benches/` use `harness = false` and drive this:
//! warmup, timed repeats, and a median/p10/p90 report, plus helpers for
//! printing figure-shaped tables.
//!
//! Besides the console tables, every bench emits a machine-readable
//! [`BenchReport`] — a `BENCH_<bench>_<date>.json` file under
//! `bench_results/` (override with the `SDDN_BENCH_DIR` env var) that
//! records machine info, workload shape, per-phase wall times, and
//! headline metrics. Committed per PR, these files form the repo's
//! performance trajectory; `sddnewton bench-validate` and the schema
//! tests below keep them well-formed. See `docs/BENCHMARKS.md` for the
//! schema field by field.

#![warn(missing_docs)]

use crate::config::json::Json;
use crate::util::{Summary, Timer};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Untimed runs before sampling starts (cache/JIT-ish warmup).
    pub warmup_iters: usize,
    /// Timed samples contributing to the reported [`Summary`].
    pub sample_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 1, sample_iters: 5 }
    }
}

impl BenchOpts {
    /// CI smoke settings: 1 warmup / 1 sample, just enough to prove the
    /// bench target still builds and runs.
    pub fn smoke() -> Self {
        BenchOpts { warmup_iters: 1, sample_iters: 1 }
    }
}

/// True when the bench was invoked with `--smoke`
/// (`cargo bench --bench <name> -- --smoke`). Benches shrink their
/// workloads under smoke so CI can keep every target green.
pub fn is_smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Parse the shared bench CLI (benches use `harness = false`):
/// `--smoke` selects [`BenchOpts::smoke`]; `--threads N` pins the
/// process-wide parallelism knob (see [`crate::par`]).
pub fn cli_opts() -> BenchOpts {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        if let Some(n) = args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
            crate::par::set_threads(n);
        }
    }
    if is_smoke() {
        BenchOpts::smoke()
    } else {
        BenchOpts::default()
    }
}

/// Time a closure repeatedly; prints and returns the summary (seconds).
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> Summary {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.sample_iters);
    for _ in 0..opts.sample_iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name:<40} median {:>10.4}s  p10 {:>10.4}s  p90 {:>10.4}s  (n={})",
        s.median, s.p10, s.p90, s.n
    );
    s
}

/// Print a section header for a figure reproduction.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// The `BENCH_*.json` schema version this crate writes. Bump only with a
/// matching update to `docs/BENCHMARKS.md` and the schema-stability test.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// A machine-readable record of one bench invocation, persisted as
/// `BENCH_<bench>_<date>.json`.
///
/// Build one at the top of a bench (`BenchReport::new`), add workload
/// shape via [`config_num`](BenchReport::config_num) /
/// [`config_str`](BenchReport::config_str), wall times via
/// [`phase`](BenchReport::phase), headline numbers via
/// [`metric`](BenchReport::metric) / [`summary`](BenchReport::summary),
/// then [`write`](BenchReport::write) before exiting.
pub struct BenchReport {
    bench: String,
    smoke: bool,
    config: BTreeMap<String, Json>,
    phases: Vec<(String, f64)>,
    metrics: BTreeMap<String, Json>,
}

impl BenchReport {
    /// Start a report for the named bench. Smoke mode is captured from
    /// the process arguments (see [`is_smoke`]).
    pub fn new(bench: &str) -> BenchReport {
        BenchReport {
            bench: bench.to_string(),
            smoke: is_smoke(),
            config: BTreeMap::new(),
            phases: Vec::new(),
            metrics: BTreeMap::new(),
        }
    }

    /// Record a numeric workload parameter (n, m, k, p, iters, eps, …).
    pub fn config_num(&mut self, key: &str, value: f64) {
        self.config.insert(key.to_string(), Json::Num(value));
    }

    /// Record a string workload parameter (graph kind, algorithm, …).
    pub fn config_str(&mut self, key: &str, value: &str) {
        self.config.insert(key.to_string(), Json::Str(value.to_string()));
    }

    /// Append a named phase with its wall time in seconds. Phases keep
    /// insertion order in the emitted JSON.
    pub fn phase(&mut self, name: &str, secs: f64) {
        self.phases.push((name.to_string(), secs));
    }

    /// Record a scalar headline metric (bytes on wire, speedup, …).
    pub fn metric(&mut self, key: &str, value: f64) {
        self.metrics.insert(key.to_string(), Json::Num(value));
    }

    /// Record a full timing [`Summary`] as a nested object
    /// (`{n, mean, std, min, p10, median, p90, max}`).
    pub fn summary(&mut self, key: &str, s: &Summary) {
        let mut m = BTreeMap::new();
        m.insert("n".to_string(), Json::Num(s.n as f64));
        m.insert("mean".to_string(), Json::Num(s.mean));
        m.insert("std".to_string(), Json::Num(s.std));
        m.insert("min".to_string(), Json::Num(s.min));
        m.insert("p10".to_string(), Json::Num(s.p10));
        m.insert("median".to_string(), Json::Num(s.median));
        m.insert("p90".to_string(), Json::Num(s.p90));
        m.insert("max".to_string(), Json::Num(s.max));
        self.metrics.insert(key.to_string(), Json::Obj(m));
    }

    /// Serialize to the schema-versioned JSON document.
    pub fn to_json(&self) -> Json {
        let mut machine = BTreeMap::new();
        machine.insert("os".to_string(), Json::Str(std::env::consts::OS.to_string()));
        machine.insert("arch".to_string(), Json::Str(std::env::consts::ARCH.to_string()));
        let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        machine.insert("logical_cpus".to_string(), Json::Num(cpus as f64));
        machine.insert("bench_threads".to_string(), Json::Num(crate::par::threads() as f64));
        if let Some(model) = cpu_model() {
            machine.insert("cpu_model".to_string(), Json::Str(model));
        }

        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|(name, secs)| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(name.clone()));
                o.insert("secs".to_string(), Json::Num(*secs));
                Json::Obj(o)
            })
            .collect();

        let mut doc = BTreeMap::new();
        doc.insert(
            "schema_version".to_string(),
            Json::Num(BENCH_SCHEMA_VERSION as f64),
        );
        doc.insert("bench".to_string(), Json::Str(self.bench.clone()));
        doc.insert("date".to_string(), Json::Str(utc_date()));
        doc.insert("smoke".to_string(), Json::Bool(self.smoke));
        doc.insert("machine".to_string(), Json::Obj(machine));
        doc.insert("config".to_string(), Json::Obj(self.config.clone()));
        doc.insert("phases".to_string(), Json::Arr(phases));
        doc.insert("metrics".to_string(), Json::Obj(self.metrics.clone()));
        Json::Obj(doc)
    }

    /// Write `BENCH_<bench>_<date>.json` into `dir`, returning the path.
    ///
    /// A trajectory is append-only: if today's file already exists (a
    /// second run of the same bench on the same UTC date), the report is
    /// deduplicated to `BENCH_<bench>_<date>.1.json`, `.2.json`, … —
    /// never silently overwriting the earlier point. The suffixed names
    /// still match the `BENCH_*.json` shape `bench-validate` scans.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let stem = format!("BENCH_{}_{}", self.bench, utc_date());
        let mut path = dir.join(format!("{stem}.json"));
        let mut suffix = 0u32;
        while path.exists() {
            suffix += 1;
            if suffix > 10_000 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::AlreadyExists,
                    format!("more than 10000 same-day reports for {stem}"),
                ));
            }
            path = dir.join(format!("{stem}.{suffix}.json"));
        }
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Write into the default trajectory directory — `$SDDN_BENCH_DIR`
    /// when set, else `bench_results/` at the workspace root — and print
    /// the emitted path (greppable in bench logs).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = match std::env::var("SDDN_BENCH_DIR") {
            Ok(d) if !d.is_empty() => PathBuf::from(d),
            _ => Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("bench_results"),
        };
        let path = self.write_to(&dir)?;
        println!("bench report written to {}", path.display());
        Ok(path)
    }
}

/// Best-effort CPU model string from `/proc/cpuinfo` (absent on
/// non-Linux hosts; the field is simply omitted).
fn cpu_model() -> Option<String> {
    let text = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    let line = text.lines().find(|l| l.starts_with("model name"))?;
    Some(line.split(':').nth(1)?.trim().to_string())
}

/// Today's UTC calendar date as `YYYY-MM-DD` (no time-zone database in a
/// dependency-free crate; UTC is what CI records anyway).
pub fn utc_date() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Proleptic-Gregorian date from days since 1970-01-01 (Hinnant's
/// `civil_from_days` algorithm).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = (if z >= 0 { z } else { z - 146_096 }) / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Validate a parsed `BENCH_*.json` document against the schema this
/// crate writes. Returns a human-readable reason on the first violation.
/// Shared by `sddnewton bench-validate` and the schema tests.
pub fn validate_report(doc: &Json) -> Result<(), String> {
    let obj = doc.as_obj().ok_or("top level is not an object")?;
    let version = obj
        .get("schema_version")
        .and_then(Json::as_usize)
        .ok_or("missing numeric schema_version")?;
    if version as u64 != BENCH_SCHEMA_VERSION {
        return Err(format!(
            "schema_version {version} != supported {BENCH_SCHEMA_VERSION}"
        ));
    }
    let bench = obj.get("bench").and_then(Json::as_str).ok_or("missing string bench")?;
    if bench.is_empty() {
        return Err("empty bench name".to_string());
    }
    let date = obj.get("date").and_then(Json::as_str).ok_or("missing string date")?;
    let bytes = date.as_bytes();
    let date_ok = bytes.len() == 10
        && bytes[4] == b'-'
        && bytes[7] == b'-'
        && bytes
            .iter()
            .enumerate()
            .all(|(i, &c)| i == 4 || i == 7 || c.is_ascii_digit());
    if !date_ok {
        return Err(format!("date {date:?} is not YYYY-MM-DD"));
    }
    obj.get("smoke").and_then(Json::as_bool).ok_or("missing bool smoke")?;
    let machine = obj.get("machine").and_then(Json::as_obj).ok_or("missing machine object")?;
    for key in ["os", "arch"] {
        machine
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("machine missing {key}"))?;
    }
    for key in ["logical_cpus", "bench_threads"] {
        machine
            .get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("machine missing {key}"))?;
    }
    obj.get("config").and_then(Json::as_obj).ok_or("missing config object")?;
    let phases = obj.get("phases").and_then(Json::as_arr).ok_or("missing phases array")?;
    for (i, ph) in phases.iter().enumerate() {
        ph.get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("phase {i} missing name"))?;
        let secs = ph
            .get("secs")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("phase {i} missing secs"))?;
        if !secs.is_finite() || secs < 0.0 {
            return Err(format!("phase {i} has bad secs {secs}"));
        }
    }
    obj.get("metrics").and_then(Json::as_obj).ok_or("missing metrics object")?;
    Ok(())
}

/// Print a key/value result row (greppable in bench output).
pub fn result_row(key: &str, value: impl std::fmt::Display) {
    println!("result {key} = {value}");
}

/// Which direction of change counts as a regression for a metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricDirection {
    /// Growth is a regression (bytes, messages, seconds, iterations, …).
    LowerIsBetter,
    /// Shrinkage is a regression (speedups, throughputs, rates).
    HigherIsBetter,
}

/// Classify a metric key by naming convention: `speedup`, `throughput`,
/// and `rate` keys improve upward, everything else (bytes, messages,
/// seconds, iteration counts) improves downward. `bench-diff` relies on
/// this, so metric names in benches should follow the convention.
pub fn metric_direction(key: &str) -> MetricDirection {
    let k = key.to_ascii_lowercase();
    if k.contains("speedup") || k.contains("throughput") || k.contains("rate") {
        MetricDirection::HigherIsBetter
    } else {
        MetricDirection::LowerIsBetter
    }
}

/// One metric compared between a baseline and a candidate report.
#[derive(Debug, Clone)]
pub struct MetricDiff {
    /// Bench name both reports agree on.
    pub bench: String,
    /// Metric key (summaries compare their `median` field).
    pub key: String,
    /// Baseline value.
    pub baseline: f64,
    /// Candidate value.
    pub candidate: f64,
    /// Fractional change in the *bad* direction (positive = worse),
    /// relative to the baseline magnitude.
    pub worse_frac: f64,
    /// The change exceeds the tolerance — a regression.
    pub regressed: bool,
}

/// Outcome of [`diff_reports`].
#[derive(Debug, Clone, Default)]
pub struct BenchDiff {
    /// Per-metric comparison rows (baseline metric order).
    pub rows: Vec<MetricDiff>,
    /// Baseline metric keys the candidate no longer reports. A vanished
    /// metric is treated as a regression — silently dropping a headline
    /// number would otherwise hide an arbitrarily large one.
    pub missing: Vec<String>,
}

impl BenchDiff {
    /// Any metric regressed beyond tolerance (or vanished).
    pub fn regressed(&self) -> bool {
        !self.missing.is_empty() || self.rows.iter().any(|r| r.regressed)
    }
}

/// Numeric value of a metric entry: scalars directly, timing summaries by
/// their `median`.
fn metric_value(v: &Json) -> Option<f64> {
    v.as_f64().or_else(|| v.get("median").and_then(Json::as_f64))
}

/// Compare two parsed `BENCH_*.json` documents of the same bench.
///
/// Every numeric baseline metric (summaries via their median) is matched
/// against the candidate's metric of the same key and judged by
/// [`metric_direction`]: a change worse than `tol` (a fraction of the
/// baseline magnitude, e.g. `0.05` = 5 %) is a regression, as is a
/// baseline metric the candidate dropped. Candidate-only metrics are
/// ignored — adding instrumentation is not a regression. Both documents
/// must validate ([`validate_report`]) and name the same bench.
pub fn diff_reports(baseline: &Json, candidate: &Json, tol: f64) -> Result<BenchDiff, String> {
    validate_report(baseline).map_err(|e| format!("baseline: {e}"))?;
    validate_report(candidate).map_err(|e| format!("candidate: {e}"))?;
    let bench = baseline.get("bench").and_then(Json::as_str).unwrap_or_default();
    let cand_bench = candidate.get("bench").and_then(Json::as_str).unwrap_or_default();
    if bench != cand_bench {
        return Err(format!("bench mismatch: baseline '{bench}' vs candidate '{cand_bench}'"));
    }
    let base_metrics = baseline.get("metrics").and_then(Json::as_obj).expect("validated");
    let cand_metrics = candidate.get("metrics").and_then(Json::as_obj).expect("validated");
    let mut out = BenchDiff::default();
    for (key, bval) in base_metrics {
        let Some(base) = metric_value(bval) else { continue };
        let Some(cand) = cand_metrics.get(key).and_then(metric_value) else {
            out.missing.push(key.clone());
            continue;
        };
        let delta = match metric_direction(key) {
            MetricDirection::LowerIsBetter => cand - base,
            MetricDirection::HigherIsBetter => base - cand,
        };
        let worse_frac = delta / base.abs().max(1e-12);
        out.rows.push(MetricDiff {
            bench: bench.to_string(),
            key: key.clone(),
            baseline: base,
            candidate: cand,
            worse_frac,
            regressed: worse_frac > tol,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0;
        let s = bench(
            "noop",
            &BenchOpts { warmup_iters: 1, sample_iters: 3 },
            || {
                count += 1;
            },
        );
        assert_eq!(count, 4);
        assert_eq!(s.n, 3);
        assert!(s.median >= 0.0);
    }

    fn sample_report() -> BenchReport {
        let mut rep = BenchReport::new("unit_test");
        rep.config_num("n", 1000.0);
        rep.config_num("m", 3000.0);
        rep.config_num("k", 4.0);
        rep.config_str("graph", "expander");
        rep.phase("build", 0.25);
        rep.phase("solve", 1.5);
        rep.metric("wire_bytes", 1234.0);
        rep.metric("speedup_vs_serial", 1.7);
        rep.summary("iter_secs", &Summary::of(&[0.5, 0.6, 0.7]));
        rep
    }

    #[test]
    fn report_roundtrips_through_json_and_validates() {
        let doc = sample_report().to_json();
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("emitted JSON must parse");
        assert_eq!(parsed, doc, "Display/parse round-trip must be lossless");
        validate_report(&parsed).expect("emitted report must validate");
        // Spot-check content survived.
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("unit_test"));
        assert_eq!(
            parsed.get("config").unwrap().get("n").unwrap().as_usize(),
            Some(1000)
        );
        let phases = parsed.get("phases").unwrap().as_arr().unwrap();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].get("name").unwrap().as_str(), Some("build"));
        let iter = parsed.get("metrics").unwrap().get("iter_secs").unwrap();
        assert_eq!(iter.get("median").unwrap().as_f64(), Some(0.6));
    }

    /// The schema is a public contract (docs/BENCHMARKS.md documents it
    /// field by field, CI validates committed files against it). Pin the
    /// exact top-level key set and version so accidental drift fails here
    /// instead of in a later PR's trajectory diff.
    #[test]
    fn schema_is_stable() {
        assert_eq!(BENCH_SCHEMA_VERSION, 1);
        let doc = sample_report().to_json();
        let obj = doc.as_obj().unwrap();
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "bench",
                "config",
                "date",
                "machine",
                "metrics",
                "phases",
                "schema_version",
                "smoke"
            ],
            "BENCH_*.json top-level keys changed — bump BENCH_SCHEMA_VERSION \
             and update docs/BENCHMARKS.md"
        );
        let machine = doc.get("machine").unwrap().as_obj().unwrap();
        for key in ["os", "arch", "logical_cpus", "bench_threads"] {
            assert!(machine.contains_key(key), "machine must carry {key}");
        }
        let date = doc.get("date").unwrap().as_str().unwrap();
        assert_eq!(date.len(), 10);
        assert_eq!(&date[4..5], "-");
        assert_eq!(&date[7..8], "-");
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate_report(&Json::Num(3.0)).is_err(), "non-object");
        let mut doc = sample_report().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("schema_version".to_string(), Json::Num(99.0));
        }
        assert!(validate_report(&doc).is_err(), "wrong version");
        let mut doc = sample_report().to_json();
        if let Json::Obj(m) = &mut doc {
            m.remove("phases");
        }
        assert!(validate_report(&doc).is_err(), "missing phases");
        let mut doc = sample_report().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("date".to_string(), Json::Str("yesterday".to_string()));
        }
        assert!(validate_report(&doc).is_err(), "bad date");
    }

    #[test]
    fn write_to_emits_a_parseable_file() {
        let dir = std::env::temp_dir().join("sddn_benchkit_test");
        let rep = sample_report();
        let path = rep.write_to(&dir).expect("write must succeed");
        let name = path.file_name().unwrap().to_str().unwrap();
        assert!(name.starts_with("BENCH_unit_test_"), "got {name}");
        assert!(name.ends_with(".json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.trim()).expect("file must hold valid JSON");
        validate_report(&parsed).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn same_day_rerun_is_deduplicated_not_overwritten() {
        // Before the fix, a second run of the same bench on the same UTC
        // date reused the exact same path and silently clobbered the
        // earlier trajectory point.
        let dir = std::env::temp_dir().join(format!("sddn_benchkit_dedupe_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut first = sample_report();
        first.metric("which_run", 1.0);
        let mut second = sample_report();
        second.metric("which_run", 2.0);
        let p1 = first.write_to(&dir).expect("first write");
        let p2 = second.write_to(&dir).expect("second write");
        let p3 = second.write_to(&dir).expect("third write");
        assert_ne!(p1, p2, "second same-day run must not reuse the first path");
        assert_ne!(p2, p3);
        let n2 = p2.file_name().unwrap().to_str().unwrap();
        assert!(n2.starts_with("BENCH_unit_test_") && n2.ends_with(".1.json"), "got {n2}");
        // The first point survives, unmodified.
        let text1 = std::fs::read_to_string(&p1).unwrap();
        assert!(text1.contains("\"which_run\":1"), "first report clobbered: {text1}");
        for p in [&p1, &p2, &p3] {
            let parsed = Json::parse(std::fs::read_to_string(p).unwrap().trim()).unwrap();
            validate_report(&parsed).unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn diff_passes_on_self_compare_and_flags_direction_aware_regressions() {
        let doc = sample_report().to_json();
        // Self-compare: every metric identical, nothing regresses.
        let same = diff_reports(&doc, &doc, 0.05).unwrap();
        assert!(!same.regressed());
        assert!(same.missing.is_empty());
        assert!(same.rows.iter().all(|r| r.worse_frac == 0.0));
        // wire_bytes (lower-is-better) grows 50 % → regression; the same
        // growth on speedup_vs_serial (higher-is-better) is an improvement.
        let mut worse = sample_report();
        worse.metric("wire_bytes", 1234.0 * 1.5);
        worse.metric("speedup_vs_serial", 1.7 * 1.5);
        worse.summary("iter_secs", &Summary::of(&[0.5, 0.6, 0.7]));
        let diff = diff_reports(&doc, &worse.to_json(), 0.05).unwrap();
        assert!(diff.regressed());
        let by_key = |k: &str| diff.rows.iter().find(|r| r.key == k).unwrap();
        assert!(by_key("wire_bytes").regressed);
        assert!(!by_key("speedup_vs_serial").regressed);
        assert!(by_key("speedup_vs_serial").worse_frac < 0.0, "improvement is negative");
        assert!(!by_key("iter_secs").regressed, "identical summary median");
        // A shrinking speedup IS a regression.
        let mut slower = sample_report();
        slower.metric("speedup_vs_serial", 1.7 * 0.5);
        let shrunk = diff_reports(&doc, &slower.to_json(), 0.05).unwrap();
        assert!(shrunk.rows.iter().find(|r| r.key == "speedup_vs_serial").unwrap().regressed);
    }

    #[test]
    fn diff_tolerates_changes_within_tol_and_flags_vanished_metrics() {
        let doc = sample_report().to_json();
        let mut slight = sample_report();
        slight.metric("wire_bytes", 1234.0 * 1.04); // +4 % < 5 % tol
        let diff = diff_reports(&doc, &slight.to_json(), 0.05).unwrap();
        assert!(!diff.regressed());
        // Candidate that silently drops a baseline metric regresses.
        let mut dropped = BenchReport::new("unit_test");
        dropped.config_num("n", 1000.0);
        dropped.metric("wire_bytes", 1234.0);
        let diff = diff_reports(&doc, &dropped.to_json(), 0.05).unwrap();
        assert!(diff.regressed());
        assert!(diff.missing.contains(&"speedup_vs_serial".to_string()));
        // Candidate-only metrics are fine.
        let mut extra = sample_report();
        extra.metric("new_counter", 7.0);
        assert!(!diff_reports(&doc, &extra.to_json(), 0.05).unwrap().regressed());
        // Mismatched bench names refuse to compare.
        let other = BenchReport::new("other_bench").to_json();
        assert!(diff_reports(&doc, &other, 0.05).is_err());
    }

    #[test]
    fn metric_direction_convention() {
        assert_eq!(metric_direction("wire_bytes"), MetricDirection::LowerIsBetter);
        assert_eq!(metric_direction("iter_secs"), MetricDirection::LowerIsBetter);
        assert_eq!(metric_direction("speedup_vs_serial"), MetricDirection::HigherIsBetter);
        assert_eq!(metric_direction("rows_per_sec_rate"), MetricDirection::HigherIsBetter);
    }

    #[test]
    fn civil_date_conversion_is_correct() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // leap year start
        assert_eq!(civil_from_days(19_782), (2024, 2, 29)); // leap day
        assert_eq!(civil_from_days(20_666), (2026, 8, 1));
    }

    #[test]
    fn civil_date_handles_epoch_leap_and_century_boundaries() {
        // Epoch day zero and its neighbors.
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(1), (1970, 1, 2));
        assert_eq!(civil_from_days(364), (1970, 12, 31));
        // 2000 is a leap year (divisible by 400): Feb 29 exists.
        assert_eq!(civil_from_days(10_957), (2000, 1, 1));
        assert_eq!(civil_from_days(11_016), (2000, 2, 29));
        assert_eq!(civil_from_days(11_017), (2000, 3, 1));
        // 2100 is NOT a leap year (divisible by 100, not by 400):
        // Feb 28 is followed directly by Mar 1.
        assert_eq!(civil_from_days(47_482), (2100, 1, 1));
        assert_eq!(civil_from_days(47_540), (2100, 2, 28));
        assert_eq!(civil_from_days(47_541), (2100, 3, 1));
    }

    #[test]
    fn utc_date_is_iso_shaped() {
        let d = utc_date();
        assert_eq!(d.len(), 10, "{d}");
        let bytes = d.as_bytes();
        assert_eq!(bytes[4], b'-');
        assert_eq!(bytes[7], b'-');
        assert!(d.chars().enumerate().all(|(i, c)| if i == 4 || i == 7 {
            c == '-'
        } else {
            c.is_ascii_digit()
        }));
        // The current date is on or after the day this test was written.
        assert!(d.as_str() >= "2026-08-08", "clock before authoring date: {d}");
    }
}
