//! Minimal benchmarking harness (criterion substitute, offline sandbox).
//!
//! Benches under `rust/benches/` use `harness = false` and drive this:
//! warmup, timed repeats, and a median/p10/p90 report, plus helpers for
//! printing figure-shaped tables.

use crate::util::{Summary, Timer};

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 1, sample_iters: 5 }
    }
}

impl BenchOpts {
    /// CI smoke settings: 1 warmup / 1 sample, just enough to prove the
    /// bench target still builds and runs.
    pub fn smoke() -> Self {
        BenchOpts { warmup_iters: 1, sample_iters: 1 }
    }
}

/// True when the bench was invoked with `--smoke`
/// (`cargo bench --bench <name> -- --smoke`). Benches shrink their
/// workloads under smoke so CI can keep every target green.
pub fn is_smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Parse the shared bench CLI (benches use `harness = false`):
/// `--smoke` selects [`BenchOpts::smoke`]; `--threads N` pins the
/// process-wide parallelism knob (see [`crate::par`]).
pub fn cli_opts() -> BenchOpts {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        if let Some(n) = args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
            crate::par::set_threads(n);
        }
    }
    if is_smoke() {
        BenchOpts::smoke()
    } else {
        BenchOpts::default()
    }
}

/// Time a closure repeatedly; prints and returns the summary (seconds).
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> Summary {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.sample_iters);
    for _ in 0..opts.sample_iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name:<40} median {:>10.4}s  p10 {:>10.4}s  p90 {:>10.4}s  (n={})",
        s.median, s.p10, s.p90, s.n
    );
    s
}

/// Print a section header for a figure reproduction.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a key/value result row (greppable in bench output).
pub fn result_row(key: &str, value: impl std::fmt::Display) {
    println!("result {key} = {value}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0;
        let s = bench(
            "noop",
            &BenchOpts { warmup_iters: 1, sample_iters: 3 },
            || {
                count += 1;
            },
        );
        assert_eq!(count, 4);
        assert_eq!(s.n, 3);
        assert!(s.median >= 0.0);
    }
}
