//! Summary statistics over samples (criterion-substitute reporting).

/// Summary of a sample set: mean, std, median, p10/p90, min/max.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p10: f64,
    pub median: f64,
    pub p90: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p10: percentile(&sorted, 0.10),
            median: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Default denominator floor for [`rel_err`].
pub const REL_ERR_EPS: f64 = 1e-12;

/// Relative error `|a − b| / max(|b|, eps)` with an explicit denominator
/// floor.
///
/// The floor caps the reported error near a zero baseline: whenever
/// `|b| < eps` the result degrades to `|a − b| / eps` — an *absolute*
/// error in units of `eps`, not a ratio. Two denormal-tiny values that
/// differ by twenty orders of magnitude in ratio therefore compare as
/// "equal" under any `eps` far above them; callers comparing quantities
/// that can legitimately live below the floor (bench-diff thresholds,
/// near-converged objectives) must pick `eps` at or below the smallest
/// magnitude they consider meaningful, or pre-check `|b| >= eps`.
pub fn rel_err_eps(a: f64, b: f64, eps: f64) -> f64 {
    (a - b).abs() / b.abs().max(eps)
}

/// Relative error with the default [`REL_ERR_EPS`] floor — see
/// [`rel_err_eps`] for the contract at near-zero baselines.
pub fn rel_err(a: f64, b: f64) -> f64 {
    rel_err_eps(a, b, REL_ERR_EPS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_tolerates_nan() {
        // One NaN timing sample must not abort a bench run: total_cmp
        // sorts positive NaN after +inf, so order stats stay deterministic.
        let s = Summary::of(&[1.0, f64::NAN, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
        assert!(s.mean.is_nan());
        assert!(s.std.is_nan());
        assert_eq!(s.median, 2.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }

    #[test]
    fn rel_err_basics() {
        assert!(rel_err(1.0, 1.0) < 1e-15);
        assert!((rel_err(1.1, 1.0) - 0.1).abs() < 1e-12);
    }

    /// The documented boundary contract: below the floor, `rel_err`
    /// reports absolute error in units of eps — NOT the true ratio.
    /// Two denormal-tiny values whose ratio is 1e20 read as ~0 under the
    /// default floor; an eps chosen below them recovers the discrepancy.
    #[test]
    fn rel_err_floor_contract_at_denormal_baselines() {
        let (a, b) = (1e-300f64, 1e-320f64);
        // Default floor: silently ~0 — the trap the explicit API names.
        assert!(rel_err(a, b) < 1e-287);
        // Same values with an honest floor: the discrepancy is huge.
        assert!(rel_err_eps(a, b, 1e-321) > 1e19);
        // At/above the floor the two forms agree exactly.
        assert_eq!(rel_err(3.0, 2.0), rel_err_eps(3.0, 2.0, REL_ERR_EPS));
        // eps floors the denominator, never the numerator.
        assert_eq!(rel_err_eps(5.0, 0.0, 1.0), 5.0);
    }
}
