//! Minimal leveled logger writing to stderr. Controlled by the
//! `SDDN_LOG` environment variable (`error|warn|info|debug|trace`,
//! default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_level() -> u8 {
    let lvl = match std::env::var("SDDN_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level as u8 (lazily initialized from the environment).
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_level()
    } else {
        l
    }
}

/// Override the level programmatically (used by tests and the CLI -q/-v).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True if messages at `l` should be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Core log function; prefer the macros.
pub fn log(l: Level, args: std::fmt::Arguments) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at info level.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}

/// Log at warn level.
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

/// Log at debug level.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
