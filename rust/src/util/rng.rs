//! PCG-XSL-RR 128/64 pseudo-random number generator.
//!
//! Deterministic, seedable, fast, and good enough statistically for
//! synthetic dataset generation and randomized graph construction.
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).

/// PCG64 generator (128-bit state, 64-bit output, XSL-RR output function).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id so independent
    /// components (nodes, workers) can draw non-overlapping sequences.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let l = m as u64;
            if l >= bound || l >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generation is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(5);
        let idx = r.sample_indices(100, 40);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(42, 1);
        let mut b = Pcg64::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
