//! Small self-contained utilities: PRNG, timing, summary statistics and a
//! minimal logger. The sandbox has no network access to crates.io, so these
//! replace `rand`, `log`/`env_logger` and friends.

pub mod rng;
pub mod timer;
pub mod stats;
pub mod logging;
pub mod pool;

pub use pool::BufferPool;
pub use rng::Pcg64;
pub use timer::Timer;
pub use stats::Summary;
