//! Wall-clock timing helpers used by the harness and `benchkit`.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since construction / last reset.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds as f64.
    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Reset the start point.
    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
