//! A tiny free-list of `Vec<f64>` buffers for the iteration hot loops.
//!
//! The partitioned SDD-Newton inner loop used to allocate fresh `Vec`s
//! every round (solver scratch, boundary payloads, all-reduce copies).
//! At 10⁶ nodes that churn dominates; a [`BufferPool`] turns it into
//! steady-state reuse. `take` hands out a zeroed buffer of the exact
//! requested length — bit-identical semantics to `vec![0.0; len]` — and
//! `put` returns it for the next round.

/// A free-list of reusable `Vec<f64>` buffers.
///
/// Buffers handed out by [`take`](BufferPool::take) are always zeroed
/// and exactly the requested length, so swapping `vec![0.0; len]` for
/// `pool.take(len)` never changes numerical results. The list is
/// length-capped so a one-off huge round can't pin memory forever.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Vec<Vec<f64>>,
}

/// Maximum number of parked buffers; excess `put`s are dropped.
const MAX_PARKED: usize = 64;

impl BufferPool {
    /// An empty pool.
    pub fn new() -> BufferPool {
        BufferPool { free: Vec::new() }
    }

    /// Get a zeroed buffer of exactly `len` elements, reusing a parked
    /// allocation when one is available.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        match self.free.pop() {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Get a buffer holding a copy of `src` (the pooled equivalent of
    /// `src.to_vec()`), reusing a parked allocation when available.
    pub fn take_copy(&mut self, src: &[f64]) -> Vec<f64> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// Park a buffer for reuse. Contents need not be cleared; `take`
    /// re-zeroes. Beyond the cap the buffer is simply dropped.
    pub fn put(&mut self, v: Vec<f64>) {
        if self.free.len() < MAX_PARKED && v.capacity() > 0 {
            self.free.push(v);
        }
    }

    /// Number of currently parked buffers (diagnostics/tests).
    pub fn parked(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_sized() {
        let mut pool = BufferPool::new();
        let mut a = pool.take(5);
        assert_eq!(a, vec![0.0; 5]);
        a.iter_mut().for_each(|x| *x = 7.0);
        pool.put(a);
        let b = pool.take(3);
        assert_eq!(b, vec![0.0; 3], "recycled buffer must be re-zeroed");
        let c = pool.take(9);
        assert_eq!(c, vec![0.0; 9], "growth past old capacity still zeroed");
    }

    #[test]
    fn reuses_capacity() {
        let mut pool = BufferPool::new();
        let a = pool.take(100);
        let ptr = a.as_ptr();
        pool.put(a);
        let b = pool.take(50);
        assert_eq!(b.as_ptr(), ptr, "shrinking take must reuse the parked allocation");
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn take_copy_matches_to_vec() {
        let mut pool = BufferPool::new();
        pool.put(vec![9.0; 16]);
        let src = [1.0, 2.0, 3.0];
        let v = pool.take_copy(&src);
        assert_eq!(v, src.to_vec());
    }

    #[test]
    fn cap_bounds_parked() {
        let mut pool = BufferPool::new();
        for _ in 0..(MAX_PARKED + 10) {
            pool.put(vec![0.0; 4]);
        }
        assert_eq!(pool.parked(), MAX_PARKED);
    }
}
