//! Minimal JSON parser (serde is unavailable offline). Supports the full
//! JSON grammar minus exotic number forms; used for the artifact manifest
//! and experiment config files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse errors with byte offsets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As usize (validating integrality).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    /// Four hex digits of a `\uXXXX` escape.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            code = code * 16 + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
        }
        Ok(code)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // UTF-16 high surrogate: must be immediately
                            // followed by an escaped low surrogate.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("invalid \\u code point"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.b.len());
                    if let Ok(s) = std::str::from_utf8(&self.b[start..end]) {
                        out.push_str(s);
                        self.pos = end;
                    } else {
                        return Err(self.err("invalid utf-8"));
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "\"{k}\":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"αβγ\"").unwrap();
        assert_eq!(j.as_str(), Some("αβγ"));
    }

    #[test]
    fn surrogate_pair_escapes_combine() {
        // U+1F600 GRINNING FACE as a UTF-16 surrogate pair.
        let j = Json::parse(r#""\uD83D\uDE00""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
        // Pair embedded in surrounding text.
        let j = Json::parse(r#""a\uD83D\uDE00b""#).unwrap();
        assert_eq!(j.as_str(), Some("a😀b"));
    }

    #[test]
    fn unpaired_surrogates_are_rejected() {
        // Lone high surrogate (end of string, non-escape, or wrong escape).
        assert!(Json::parse(r#""\uD83D""#).is_err());
        assert!(Json::parse(r#""\uD83Dx""#).is_err());
        assert!(Json::parse(r#""\uD83D\n""#).is_err());
        // High surrogate followed by a non-surrogate escape.
        assert!(Json::parse(r#""\uD83DA""#).is_err());
        // Lone low surrogate.
        assert!(Json::parse(r#""\uDE00""#).is_err());
    }

    #[test]
    fn astral_roundtrip_through_emitter() {
        // Raw astral chars in a parsed document must survive
        // Display → reparse bit-identically (guards BENCH_*.json).
        let j = Json::parse("{\"label\":\"scale 😀 𝄞 run\"}").unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        assert_eq!(j2.get("label").unwrap().as_str(), Some("scale 😀 𝄞 run"));
        // Escaped form parses to the same value as the raw form.
        let esc = Json::parse(r#""\uD834\uDD1E""#).unwrap();
        let raw = Json::parse("\"𝄞\"").unwrap();
        assert_eq!(esc, raw);
    }
}
