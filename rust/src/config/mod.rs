//! Experiment configuration: typed configs, JSON loading, named presets
//! matching the paper's figures (see DESIGN.md §4).

pub mod json;

pub use json::Json;

use crate::par::Parallelism;

/// Which benchmark problem to build.
#[derive(Debug, Clone, PartialEq)]
pub enum ProblemKind {
    /// Fig. 1(a,b): synthetic linear regression.
    SyntheticRegression { p: usize, m_total: usize, noise: f64, mu: f64 },
    /// Fig. 1(c–f): MNIST-like one-vs-all logistic.
    MnistLike { p: usize, m_total: usize, l1: bool, mu: f64 },
    /// Fig. 2(a,b): fMRI-like sparse logistic (smoothed L1).
    FmriLike { p: usize, m_total: usize, k_sparse: usize, mu: f64 },
    /// Fig. 2(c,d) + 3(a,b): London-Schools-like regression.
    LondonLike { m_total: usize, mu: f64 },
    /// Fig. 3(c,d): RL double cart-pole.
    RlDcp { rollouts: usize, t_len: usize, sigma: f64, mu: f64 },
}

/// Which algorithm(s) to run.
#[derive(Debug, Clone, PartialEq)]
pub enum AlgoKind {
    SddNewton { eps: f64, alpha: f64 },
    AddNewton { terms: usize, alpha: f64 },
    ExactNewton { alpha: f64 },
    Admm { beta: f64 },
    /// ADMM with the pipelined ship-at-earliest-consumer wavefront
    /// ([`crate::algorithms::admm::pipelined_ship_schedule`]):
    /// bit-identical iterates and the same 4m/iteration total, but stage
    /// s+1's boundary rows ship as soon as their own predecessors update.
    AdmmPipelined { beta: f64 },
    Gradient { alpha: f64 },
    Averaging { beta: f64 },
    NetworkNewton { k: usize, alpha: f64, epsilon: f64 },
    /// ADAPD-style communication-avoiding local-step Newton
    /// ([`crate::algorithms::local_steps::LocalNewton`]): `local_steps`
    /// inner proximal-Newton solves per outer iteration, `comm_rounds`
    /// Metropolis mixing exchanges.
    LocalNewton { eta: f64, local_steps: usize, comm_rounds: usize },
}

impl AlgoKind {
    /// Return a copy with the step-like hyper-parameter scaled by
    /// `factor`. Used by the harness's stabilization loop, which mimics
    /// the paper's per-algorithm step grid search: a diverging run is
    /// retried with a smaller step.
    pub fn scale_step(&self, factor: f64) -> AlgoKind {
        match *self {
            AlgoKind::SddNewton { eps, alpha } => AlgoKind::SddNewton { eps, alpha: alpha * factor },
            AlgoKind::AddNewton { terms, alpha } => {
                AlgoKind::AddNewton { terms, alpha: alpha * factor }
            }
            AlgoKind::ExactNewton { alpha } => AlgoKind::ExactNewton { alpha: alpha * factor },
            AlgoKind::Admm { beta } => AlgoKind::Admm { beta: beta * factor },
            AlgoKind::AdmmPipelined { beta } => AlgoKind::AdmmPipelined { beta: beta * factor },
            AlgoKind::Gradient { alpha } => AlgoKind::Gradient { alpha: alpha * factor },
            AlgoKind::Averaging { beta } => AlgoKind::Averaging { beta: beta * factor },
            AlgoKind::NetworkNewton { k, alpha, epsilon } => {
                AlgoKind::NetworkNewton { k, alpha, epsilon: epsilon * factor }
            }
            AlgoKind::LocalNewton { eta, local_steps, comm_rounds } => {
                AlgoKind::LocalNewton { eta: eta * factor, local_steps, comm_rounds }
            }
        }
    }

    /// Short id used on the CLI (`--algorithms sdd,admm,...`).
    pub fn id(&self) -> &'static str {
        match self {
            AlgoKind::SddNewton { .. } => "sdd",
            AlgoKind::AddNewton { .. } => "add",
            AlgoKind::ExactNewton { .. } => "exact",
            AlgoKind::Admm { .. } => "admm",
            AlgoKind::AdmmPipelined { .. } => "admmp",
            AlgoKind::Gradient { .. } => "grad",
            AlgoKind::Averaging { .. } => "avg",
            AlgoKind::LocalNewton { .. } => "local",
            AlgoKind::NetworkNewton { k, .. } => {
                if *k <= 1 {
                    "nn1"
                } else {
                    "nn2"
                }
            }
        }
    }

    /// Parse a CLI id with default hyper-parameters.
    pub fn from_id(id: &str) -> Option<AlgoKind> {
        Some(match id {
            "sdd" => AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 },
            "add" => AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
            "exact" => AlgoKind::ExactNewton { alpha: 1.0 },
            "admm" => AlgoKind::Admm { beta: 1.0 },
            "admmp" => AlgoKind::AdmmPipelined { beta: 1.0 },
            "local" => AlgoKind::LocalNewton { eta: 0.5, local_steps: 4, comm_rounds: 1 },
            "grad" => AlgoKind::Gradient { alpha: 0.01 },
            "avg" => AlgoKind::Averaging { beta: 0.005 },
            "nn1" => AlgoKind::NetworkNewton { k: 1, alpha: 0.1, epsilon: 1.0 },
            "nn2" => AlgoKind::NetworkNewton { k: 2, alpha: 0.1, epsilon: 1.0 },
            _ => return None,
        })
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub nodes: usize,
    pub edges: usize,
    pub problem: ProblemKind,
    pub algorithms: Vec<AlgoKind>,
    pub max_iters: usize,
    /// "native" or "pjrt".
    pub backend: String,
    /// Worker-thread budget for the parallel execution substrate
    /// (`crate::par`); `Parallelism::auto()` detects the machine.
    pub parallelism: Parallelism,
}

/// All six algorithms with the paper's tuned defaults.
pub fn default_algorithms() -> Vec<AlgoKind> {
    vec![
        AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 },
        AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
        AlgoKind::Admm { beta: 1.0 },
        AlgoKind::Gradient { alpha: 0.01 },
        AlgoKind::Averaging { beta: 0.005 },
        AlgoKind::NetworkNewton { k: 1, alpha: 0.1, epsilon: 1.0 },
        AlgoKind::NetworkNewton { k: 2, alpha: 0.1, epsilon: 1.0 },
    ]
}

impl ExperimentConfig {
    /// Named presets matching DESIGN.md §4. Sizes are the sandbox-scaled
    /// versions of the paper's setups (see §5 substitution table).
    pub fn preset(name: &str) -> Option<ExperimentConfig> {
        let cfg = match name {
            "fig1-synthetic" => ExperimentConfig {
                name: name.into(),
                seed: 7,
                nodes: 100,
                edges: 250,
                problem: ProblemKind::SyntheticRegression {
                    p: 80,
                    m_total: 20_000,
                    noise: 0.5,
                    mu: 0.05,
                },
                algorithms: default_algorithms(),
                max_iters: 60,
                backend: "pjrt".into(),
                parallelism: Parallelism::auto(),
            },
            "fig1-mnist-l2" | "fig1-mnist-l1" => ExperimentConfig {
                name: name.into(),
                seed: 11,
                nodes: 10,
                edges: 20,
                problem: ProblemKind::MnistLike {
                    p: 150,
                    m_total: 2000,
                    l1: name.ends_with("l1"),
                    mu: 0.01,
                },
                algorithms: default_algorithms(),
                max_iters: 50,
                backend: "pjrt".into(),
                parallelism: Parallelism::auto(),
            },
            "fig2-fmri" => ExperimentConfig {
                name: name.into(),
                seed: 13,
                nodes: 8,
                edges: 16,
                problem: ProblemKind::FmriLike {
                    p: 512,
                    m_total: 240,
                    k_sparse: 24,
                    mu: 0.02,
                },
                algorithms: vec![
                    AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 },
                    AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
                    AlgoKind::Admm { beta: 1.0 },
                    AlgoKind::Averaging { beta: 0.002 },
                ],
                max_iters: 40,
                backend: "pjrt".into(),
                parallelism: Parallelism::auto(),
            },
            "fig2-comm" | "fig3-london" => ExperimentConfig {
                name: name.into(),
                seed: 17,
                nodes: 50,
                edges: 150,
                problem: ProblemKind::LondonLike { m_total: 15_362, mu: 0.05 },
                algorithms: default_algorithms(),
                max_iters: 60,
                backend: "pjrt".into(),
                parallelism: Parallelism::auto(),
            },
            "fig3-rl" => ExperimentConfig {
                name: name.into(),
                seed: 19,
                nodes: 20,
                edges: 50,
                problem: ProblemKind::RlDcp {
                    rollouts: 2000,
                    t_len: 50,
                    sigma: 0.5,
                    mu: 0.05,
                },
                algorithms: default_algorithms(),
                max_iters: 60,
                backend: "pjrt".into(),
                parallelism: Parallelism::auto(),
            },
            "smoke" => ExperimentConfig {
                name: name.into(),
                seed: 3,
                nodes: 8,
                edges: 16,
                problem: ProblemKind::SyntheticRegression {
                    p: 5,
                    m_total: 160,
                    noise: 0.2,
                    mu: 0.05,
                },
                algorithms: default_algorithms(),
                max_iters: 20,
                backend: "pjrt".into(),
                parallelism: Parallelism::auto(),
            },
            _ => return None,
        };
        Some(cfg)
    }

    /// Names of all presets.
    pub fn preset_names() -> &'static [&'static str] {
        &[
            "fig1-synthetic",
            "fig1-mnist-l2",
            "fig1-mnist-l1",
            "fig2-fmri",
            "fig2-comm",
            "fig3-london",
            "fig3-rl",
            "smoke",
        ]
    }

    /// Parse from a JSON document (unknown fields rejected to catch typos).
    pub fn from_json(doc: &Json) -> Result<ExperimentConfig, String> {
        let obj = doc.as_obj().ok_or("config must be an object")?;
        let base_name = doc
            .get("preset")
            .and_then(|p| p.as_str())
            .map(|s| s.to_string());
        let mut cfg = match base_name {
            Some(p) => Self::preset(&p).ok_or(format!("unknown preset '{p}'"))?,
            None => Self::preset("smoke").unwrap(),
        };
        for (k, v) in obj {
            match k.as_str() {
                "preset" => {}
                "name" => cfg.name = v.as_str().ok_or("name must be str")?.into(),
                "seed" => cfg.seed = v.as_usize().ok_or("seed must be int")? as u64,
                "nodes" => cfg.nodes = v.as_usize().ok_or("nodes must be int")?,
                "edges" => cfg.edges = v.as_usize().ok_or("edges must be int")?,
                "max_iters" => cfg.max_iters = v.as_usize().ok_or("max_iters must be int")?,
                "backend" => cfg.backend = v.as_str().ok_or("backend must be str")?.into(),
                "threads" => {
                    cfg.parallelism =
                        Parallelism { threads: v.as_usize().ok_or("threads must be int")? }
                }
                "algorithms" => {
                    let arr = v.as_arr().ok_or("algorithms must be array")?;
                    cfg.algorithms = arr
                        .iter()
                        .map(|a| {
                            a.as_str()
                                .and_then(AlgoKind::from_id)
                                .ok_or_else(|| format!("unknown algorithm {a}"))
                        })
                        .collect::<Result<_, _>>()?;
                }
                other => return Err(format!("unknown config field '{other}'")),
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist_and_are_sane() {
        for name in ExperimentConfig::preset_names() {
            let c = ExperimentConfig::preset(name).unwrap();
            assert!(c.nodes >= 2);
            assert!(c.edges >= c.nodes - 1);
            assert!(!c.algorithms.is_empty());
        }
        assert!(ExperimentConfig::preset("nope").is_none());
    }

    #[test]
    fn fig1_matches_paper_graph() {
        let c = ExperimentConfig::preset("fig1-synthetic").unwrap();
        assert_eq!((c.nodes, c.edges), (100, 250));
        match c.problem {
            ProblemKind::SyntheticRegression { p, .. } => assert_eq!(p, 80),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn from_json_overrides() {
        let doc = Json::parse(
            r#"{"preset": "smoke", "nodes": 12, "edges": 24,
                "algorithms": ["sdd", "admm"], "max_iters": 5, "threads": 3}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(c.nodes, 12);
        assert_eq!(c.algorithms.len(), 2);
        assert_eq!(c.algorithms[0].id(), "sdd");
        assert_eq!(c.parallelism, Parallelism { threads: 3 });
    }

    #[test]
    fn from_json_rejects_unknown_fields() {
        let doc = Json::parse(r#"{"nodse": 12}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn algo_ids_roundtrip() {
        for id in ["sdd", "add", "exact", "admm", "admmp", "grad", "avg", "nn1", "nn2", "local"] {
            assert_eq!(AlgoKind::from_id(id).unwrap().id(), id);
        }
        assert!(AlgoKind::from_id("bogus").is_none());
    }

    #[test]
    fn scale_step_touches_the_step_like_knob_of_new_kinds() {
        let p = AlgoKind::AdmmPipelined { beta: 1.0 }.scale_step(0.5);
        assert_eq!(p, AlgoKind::AdmmPipelined { beta: 0.5 });
        let l = AlgoKind::LocalNewton { eta: 0.5, local_steps: 4, comm_rounds: 2 }.scale_step(0.5);
        assert_eq!(l, AlgoKind::LocalNewton { eta: 0.25, local_steps: 4, comm_rounds: 2 });
    }
}
