//! Logistic-regression local objectives (Appendix H.2).
//!
//! `f_i(θ) = −Σ_j [a_j θᵀb_j − log(1 + e^{θᵀb_j})] + μ_i m_i Ψ(θ)` with
//! Ψ the L2 norm (H.2.1) or the smoothed L1 of Eq. 73 (H.2.2):
//! `|x|_α = (1/α)[log(1+e^{−αx}) + log(1+e^{αx})]`.
//!
//! Primal recovery is the inner Newton solve of Eq. 52–54. On the PJRT
//! path the same math runs inside the AOT JAX module (`runtime`), which
//! calls the Pallas `logistic_grad_hess` kernel; this implementation is
//! the native fallback and the correctness oracle.

use super::LocalObjective;
use crate::linalg::Matrix;

/// Regularizer choice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Reg {
    /// μ m ‖θ‖².
    L2,
    /// μ m Σ_r |θ_r|_α (smoothed L1, Eq. 73) with smoothing parameter α.
    SmoothL1 { alpha: f64 },
}

/// Logistic local objective over `m_i` examples.
pub struct LogisticLocal {
    /// Feature matrix `B_i` (p × m_i), columns are examples (Eq. 57).
    pub b: Matrix,
    /// Labels `a_j ∈ {0, 1}`.
    pub a: Vec<f64>,
    /// Regularization weight μ_i.
    pub mu: f64,
    /// Regularizer.
    pub reg: Reg,
    /// Inner-Newton tolerance on ‖∇ζ‖ for primal recovery.
    pub newton_tol: f64,
    /// Inner-Newton iteration cap.
    pub newton_max_iter: usize,
}

/// Numerically safe log(1 + e^x).
#[inline]
pub fn log1pexp(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

impl LogisticLocal {
    /// Build; columns of `b` are examples.
    pub fn new(b: Matrix, a: Vec<f64>, mu: f64, reg: Reg) -> LogisticLocal {
        assert_eq!(b.cols, a.len());
        assert!(a.iter().all(|&v| v == 0.0 || v == 1.0), "labels must be 0/1");
        LogisticLocal { b, a, mu, reg, newton_tol: 1e-10, newton_max_iter: 60 }
    }

    /// m_i — number of local examples.
    pub fn m(&self) -> usize {
        self.a.len()
    }

    /// Margins `θᵀb_j` for all examples.
    fn margins(&self, theta: &[f64]) -> Vec<f64> {
        self.b.matvec_t(theta)
    }

    /// Regularizer value.
    fn reg_value(&self, theta: &[f64]) -> f64 {
        let mm = self.mu * self.m() as f64;
        match self.reg {
            Reg::L2 => mm * theta.iter().map(|v| v * v).sum::<f64>(),
            Reg::SmoothL1 { alpha } => {
                // (1/α)[log(1+e^{−αx}) + log(1+e^{αx})]
                mm * theta
                    .iter()
                    .map(|&x| (log1pexp(-alpha * x) + log1pexp(alpha * x)) / alpha)
                    .sum::<f64>()
            }
        }
    }

    /// Regularizer gradient.
    fn reg_grad(&self, theta: &[f64], out: &mut [f64]) {
        let mm = self.mu * self.m() as f64;
        match self.reg {
            Reg::L2 => {
                for (o, t) in out.iter_mut().zip(theta) {
                    *o += 2.0 * mm * t;
                }
            }
            Reg::SmoothL1 { alpha } => {
                // d|x|_α/dx = (e^{αx} − 1)/(e^{αx} + 1) = tanh(αx/2).
                for (o, &t) in out.iter_mut().zip(theta) {
                    *o += mm * (alpha * t / 2.0).tanh();
                }
            }
        }
    }

    /// Regularizer Hessian diagonal as a vector.
    fn reg_hess_diag_vec(&self, theta: &[f64]) -> Vec<f64> {
        let mm = self.mu * self.m() as f64;
        match self.reg {
            Reg::L2 => vec![2.0 * mm; theta.len()],
            Reg::SmoothL1 { alpha } => theta
                .iter()
                .map(|&t| {
                    let s = sigmoid(alpha * t);
                    2.0 * alpha * mm * s * (1.0 - s)
                })
                .collect(),
        }
    }

    /// Regularizer Hessian diagonal contribution.
    fn reg_hess_diag(&self, theta: &[f64], h: &mut Matrix) {
        let mm = self.mu * self.m() as f64;
        match self.reg {
            Reg::L2 => {
                for i in 0..theta.len() {
                    h[(i, i)] += 2.0 * mm;
                }
            }
            Reg::SmoothL1 { alpha } => {
                // d² = 2α e^{αx} / (1+e^{αx})² = 2α σ(αx)(1−σ(αx))  (Eq. 79).
                for (i, &t) in theta.iter().enumerate() {
                    let s = sigmoid(alpha * t);
                    h[(i, i)] += 2.0 * alpha * mm * s * (1.0 - s);
                }
            }
        }
    }
}

impl LocalObjective for LogisticLocal {
    fn p(&self) -> usize {
        self.b.rows
    }

    fn value(&self, theta: &[f64]) -> f64 {
        let margins = self.margins(theta);
        let mut loss = 0.0;
        for (j, &z) in margins.iter().enumerate() {
            loss += -self.a[j] * z + log1pexp(z);
        }
        loss + self.reg_value(theta)
    }

    fn gradient(&self, theta: &[f64]) -> Vec<f64> {
        let p = self.p();
        let margins = self.margins(theta);
        // δ_j = σ(z_j) − a_j  (Eq. 59); grad = B δ + reg.
        let delta: Vec<f64> = margins
            .iter()
            .zip(&self.a)
            .map(|(&z, &a)| sigmoid(z) - a)
            .collect();
        let mut g = vec![0.0; p];
        for j in 0..self.m() {
            let dj = delta[j];
            if dj != 0.0 {
                for i in 0..p {
                    g[i] += self.b[(i, j)] * dj;
                }
            }
        }
        self.reg_grad(theta, &mut g);
        g
    }

    fn hessian(&self, theta: &[f64]) -> Matrix {
        let p = self.p();
        let margins = self.margins(theta);
        let mut h = Matrix::zeros(p, p);
        // B D Bᵀ with D_jj = σ(z)(1 − σ(z))  (Eq. 60).
        for j in 0..self.m() {
            let s = sigmoid(margins[j]);
            let d = s * (1.0 - s);
            if d > 0.0 {
                let col: Vec<f64> = (0..p).map(|i| self.b[(i, j)]).collect();
                h.rank1_update(d, &col, &col);
            }
        }
        self.reg_hess_diag(theta, &mut h);
        h
    }

    fn primal_recover(&self, v: &[f64]) -> Vec<f64> {
        // Inner Newton on ζ(θ) = f_i(θ) + θᵀv (Eq. 52): warm-start at 0.
        let p = self.p();
        let mut theta = vec![0.0; p];
        for _ in 0..self.newton_max_iter {
            let mut g = self.gradient(&theta);
            for i in 0..p {
                g[i] += v[i];
            }
            let gn = crate::linalg::vector::norm2(&g);
            if gn <= self.newton_tol {
                break;
            }
            // Levenberg guard for the smooth-L1 case where the Hessian can
            // be near-singular far from the optimum.
            let step = self.solve_shifted(&theta, &g, 1e-10);
            // Backtracking on ζ.
            let zeta =
                |t: &[f64]| self.value(t) + crate::linalg::vector::dot(t, v);
            let f0 = zeta(&theta);
            let descent = crate::linalg::vector::dot(&g, &step);
            let mut alpha = 1.0;
            for _ in 0..60 {
                let cand: Vec<f64> =
                    theta.iter().zip(&step).map(|(t, s)| t - alpha * s).collect();
                if zeta(&cand) <= f0 - 1e-4 * alpha * descent {
                    theta = cand;
                    break;
                }
                alpha *= 0.5;
            }
            if alpha < 1e-17 {
                break;
            }
        }
        theta
    }

    fn export(&self) -> super::ExportData<'_> {
        super::ExportData::Logistic { b: &self.b, a: &self.a, mu: self.mu, reg: self.reg }
    }

    /// Matrix-free shifted solve: `(B D Bᵀ + reg'' + shift I) x = rhs` by
    /// CG with O(m·p) matvecs — never materializes the p×p Hessian. This
    /// is the native hot path for the m ≪ p (fMRI) regime; for small p the
    /// dense default would also do, but CG is exact here too.
    fn solve_shifted(&self, theta: &[f64], rhs: &[f64], shift: f64) -> Vec<f64> {
        let p = self.p();
        let m = self.m();
        let margins = self.margins(theta);
        let dw: Vec<f64> = margins
            .iter()
            .map(|&z| {
                let s = sigmoid(z);
                s * (1.0 - s)
            })
            .collect();
        let mut hdiag = self.reg_hess_diag_vec(theta);
        for h in hdiag.iter_mut() {
            *h += shift + 1e-12;
        }
        struct Op<'a> {
            b: &'a Matrix,
            dw: &'a [f64],
            hdiag: &'a [f64],
            m: usize,
        }
        impl crate::linalg::cg::LinOp for Op<'_> {
            fn dim(&self) -> usize {
                self.b.rows
            }
            fn apply(&self, x: &[f64], y: &mut [f64]) {
                // y = B (dw ⊙ (Bᵀ x)) + hdiag ⊙ x
                let bt_x = self.b.matvec_t(x); // (m,)
                let mut w = vec![0.0; self.m];
                for j in 0..self.m {
                    w[j] = self.dw[j] * bt_x[j];
                }
                let bw = self.b.matvec(&w); // (p,)
                for i in 0..y.len() {
                    y[i] = bw[i] + self.hdiag[i] * x[i];
                }
            }
        }
        let op = Op { b: &self.b, dw: &dw, hdiag: &hdiag, m };
        let res = crate::linalg::cg::cg_solve(
            &op,
            rhs,
            &crate::linalg::cg::CgOptions { tol: 1e-13, max_iter: 4 * p + 64, ..Default::default() },
        );
        res.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_logistic(p: usize, m: usize, reg: Reg, seed: u64) -> LogisticLocal {
        let mut rng = Pcg64::new(seed);
        let mut b = Matrix::zeros(p, m);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let w = rng.normal_vec(p);
        let a: Vec<f64> = (0..m)
            .map(|j| {
                let z: f64 = (0..p).map(|i| b[(i, j)] * w[i]).sum();
                if rng.next_f64() < sigmoid(z) {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        LogisticLocal::new(b, a, 0.05, reg)
    }

    #[test]
    fn gradient_matches_finite_difference_l2() {
        let l = random_logistic(4, 20, Reg::L2, 41);
        let mut rng = Pcg64::new(42);
        let theta = rng.normal_vec(4);
        let g = l.gradient(&theta);
        let h = 1e-6;
        for j in 0..4 {
            let mut tp = theta.clone();
            tp[j] += h;
            let mut tm = theta.clone();
            tm[j] -= h;
            let fd = (l.value(&tp) - l.value(&tm)) / (2.0 * h);
            assert!((g[j] - fd).abs() < 1e-4, "g[{j}]={} fd={fd}", g[j]);
        }
    }

    #[test]
    fn gradient_matches_finite_difference_smooth_l1() {
        let l = random_logistic(4, 20, Reg::SmoothL1 { alpha: 8.0 }, 43);
        let mut rng = Pcg64::new(44);
        let theta = rng.normal_vec(4);
        let g = l.gradient(&theta);
        let h = 1e-6;
        for j in 0..4 {
            let mut tp = theta.clone();
            tp[j] += h;
            let mut tm = theta.clone();
            tm[j] -= h;
            let fd = (l.value(&tp) - l.value(&tm)) / (2.0 * h);
            assert!((g[j] - fd).abs() < 1e-4, "g[{j}]={} fd={fd}", g[j]);
        }
    }

    #[test]
    fn hessian_matches_gradient_finite_difference() {
        let l = random_logistic(3, 15, Reg::L2, 45);
        let mut rng = Pcg64::new(46);
        let theta = rng.normal_vec(3);
        let hess = l.hessian(&theta);
        let h = 1e-6;
        for j in 0..3 {
            let mut tp = theta.clone();
            tp[j] += h;
            let mut tm = theta.clone();
            tm[j] -= h;
            let gp = l.gradient(&tp);
            let gm = l.gradient(&tm);
            for i in 0..3 {
                let fd = (gp[i] - gm[i]) / (2.0 * h);
                assert!((hess[(i, j)] - fd).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn primal_recover_stationarity() {
        for reg in [Reg::L2, Reg::SmoothL1 { alpha: 8.0 }] {
            let l = random_logistic(4, 25, reg, 47);
            let mut rng = Pcg64::new(48);
            let v = rng.normal_vec(4);
            let theta = l.primal_recover(&v);
            let g = l.gradient(&theta);
            for j in 0..4 {
                assert!((g[j] + v[j]).abs() < 1e-7, "reg={reg:?} g+v={}", g[j] + v[j]);
            }
        }
    }

    #[test]
    fn smooth_l1_approaches_l1() {
        // For large α, |x|_α → |x| + 2log(2)/α·p corrections; check derivative
        // sign structure: tanh(αx/2) ≈ sign(x).
        let l = random_logistic(3, 10, Reg::SmoothL1 { alpha: 200.0 }, 49);
        let theta = vec![0.5, -0.5, 0.0];
        let mut g = vec![0.0; 3];
        l.reg_grad(&theta, &mut g);
        let mm = l.mu * l.m() as f64;
        assert!((g[0] - mm).abs() < 1e-6);
        assert!((g[1] + mm).abs() < 1e-6);
        assert!(g[2].abs() < 1e-12);
    }

    #[test]
    fn solve_shifted_matches_dense_cholesky() {
        use crate::linalg::cholesky::Cholesky;
        for (reg, seed) in [(Reg::L2, 141u64), (Reg::SmoothL1 { alpha: 8.0 }, 142)] {
            let l = random_logistic(6, 12, reg, seed);
            let mut rng = Pcg64::new(seed + 1);
            let theta = rng.normal_vec(6);
            let rhs = rng.normal_vec(6);
            let shift = 0.37;
            let fast = l.solve_shifted(&theta, &rhs, shift);
            let mut h = l.hessian(&theta);
            for i in 0..6 {
                h[(i, i)] += shift + 1e-12;
            }
            let dense = Cholesky::factor(&h).unwrap().solve(&rhs);
            for (a, b) in fast.iter().zip(&dense) {
                assert!((a - b).abs() < 1e-7, "reg={reg:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn solve_shifted_scales_to_p_much_greater_than_m() {
        // The fMRI regime: p ≫ m must be fast and correct (matrix-free CG).
        let l = random_logistic(300, 10, Reg::SmoothL1 { alpha: 8.0 }, 143);
        let mut rng = Pcg64::new(144);
        let theta = rng.normal_vec(300);
        let rhs = rng.normal_vec(300);
        let t = crate::util::Timer::start();
        let x = l.solve_shifted(&theta, &rhs, 0.1);
        assert!(t.secs() < 1.0, "matrix-free path too slow: {}s", t.secs());
        // Verify residual via explicit hess_vec.
        let hx = l.hess_vec(&theta, &x);
        for i in 0..300 {
            let lhs = hx[i] + (0.1 + 1e-12) * x[i];
            assert!((lhs - rhs[i]).abs() < 1e-6, "row {i}: {lhs} vs {}", rhs[i]);
        }
    }

    #[test]
    fn log1pexp_stable() {
        assert!((log1pexp(0.0) - (2.0f64).ln()).abs() < 1e-12);
        assert!((log1pexp(100.0) - 100.0).abs() < 1e-12);
        assert!(log1pexp(-100.0) < 1e-40);
        assert!(log1pexp(-100.0) > 0.0);
    }

    #[test]
    fn sigmoid_stable_and_symmetric() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!((sigmoid(5.0) + sigmoid(-5.0) - 1.0).abs() < 1e-12);
        assert!(sigmoid(800.0) <= 1.0);
        assert!(sigmoid(-800.0) >= 0.0);
    }
}
