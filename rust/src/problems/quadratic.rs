//! Quadratic local objectives `f_i(θ) = θᵀP_iθ − 2c_iᵀθ + u_i` — the
//! reduction of linear regression (Appendix H.1, Eq. 44), the London
//! Schools task, and the RL reward-weighted regression (H.3, Eq. 85/86).

use super::LocalObjective;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::Matrix;

/// A quadratic local objective with cached Cholesky factor of `P_i`
/// (`P_i` must be SPD — guaranteed by the ridge term `μ_i m_i I`).
pub struct QuadraticLocal {
    /// SPD matrix `P_i` (p × p).
    pub p_mat: Matrix,
    /// Linear term `c_i`.
    pub c: Vec<f64>,
    /// Constant `u_i` (keeps objective values comparable with the paper).
    pub u: f64,
    chol: Cholesky,
}

impl QuadraticLocal {
    /// Build from `P_i`, `c_i`, `u_i`. Panics if `P_i` is not SPD.
    pub fn new(p_mat: Matrix, c: Vec<f64>, u: f64) -> QuadraticLocal {
        assert_eq!(p_mat.rows, p_mat.cols);
        assert_eq!(c.len(), p_mat.rows);
        let chol = Cholesky::factor(&p_mat).expect("P_i must be SPD (add ridge)");
        QuadraticLocal { p_mat, c, u, chol }
    }

    /// Build from raw data: columns `b_j` (p × m_i), targets `a` (m_i),
    /// ridge `μ_i`: `P = BBᵀ + μ m I`, `c = B a`, `u = aᵀa` (Eq. 44).
    pub fn from_data(b: &Matrix, a: &[f64], mu: f64) -> QuadraticLocal {
        let p = b.rows;
        let m = b.cols;
        assert_eq!(a.len(), m);
        let mut p_mat = b.matmul(&b.transpose());
        for i in 0..p {
            p_mat[(i, i)] += mu * m as f64;
        }
        // c = B a
        let mut c = vec![0.0; p];
        for j in 0..m {
            for i in 0..p {
                c[i] += b[(i, j)] * a[j];
            }
        }
        let u = a.iter().map(|v| v * v).sum();
        QuadraticLocal::new(p_mat, c, u)
    }

    /// Weighted variant for RL (H.3): `P = Σ_j R_j B_j B_jᵀ + μ m I`,
    /// `c = Σ_j R_j B_j a_j`, `u = Σ_j R_j a_jᵀa_j` where each trajectory
    /// contributes columns `B_j` (p × T) and actions `a_j` (T).
    pub fn from_weighted_trajectories(
        trajs: &[(Matrix, Vec<f64>, f64)],
        mu: f64,
    ) -> QuadraticLocal {
        assert!(!trajs.is_empty());
        let p = trajs[0].0.rows;
        let m = trajs.len();
        let mut p_mat = Matrix::zeros(p, p);
        let mut c = vec![0.0; p];
        let mut u = 0.0;
        for (b, a, r) in trajs {
            assert_eq!(b.rows, p);
            assert_eq!(a.len(), b.cols);
            let bbt = b.matmul(&b.transpose());
            p_mat.add_scaled(*r, &bbt);
            for j in 0..b.cols {
                for i in 0..p {
                    c[i] += r * b[(i, j)] * a[j];
                }
            }
            u += r * a.iter().map(|v| v * v).sum::<f64>();
        }
        for i in 0..p {
            p_mat[(i, i)] += mu * m as f64;
        }
        QuadraticLocal::new(p_mat, c, u)
    }
}

impl LocalObjective for QuadraticLocal {
    fn p(&self) -> usize {
        self.p_mat.rows
    }

    fn value(&self, theta: &[f64]) -> f64 {
        self.p_mat.quad_form(theta, theta) - 2.0 * crate::linalg::vector::dot(&self.c, theta)
            + self.u
    }

    fn gradient(&self, theta: &[f64]) -> Vec<f64> {
        // ∇f = 2Pθ − 2c.
        let mut g = self.p_mat.matvec(theta);
        for i in 0..g.len() {
            g[i] = 2.0 * g[i] - 2.0 * self.c[i];
        }
        g
    }

    fn hessian(&self, _theta: &[f64]) -> Matrix {
        // ∇²f = 2P (constant).
        let mut h = self.p_mat.clone();
        for v in h.data.iter_mut() {
            *v *= 2.0;
        }
        h
    }

    fn primal_recover(&self, v: &[f64]) -> Vec<f64> {
        // ∇f(θ) = −v ⇒ 2Pθ − 2c = −v ⇒ θ = P⁻¹(c − v/2)  (paper H.1).
        let rhs: Vec<f64> = self.c.iter().zip(v).map(|(c, vi)| c - 0.5 * vi).collect();
        self.chol.solve(&rhs)
    }

    fn hess_vec(&self, _theta: &[f64], z: &[f64]) -> Vec<f64> {
        let mut y = self.p_mat.matvec(z);
        for v in y.iter_mut() {
            *v *= 2.0;
        }
        y
    }

    fn export(&self) -> super::ExportData<'_> {
        super::ExportData::Quadratic { p_mat: &self.p_mat, c: &self.c }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_local(p: usize, m: usize, seed: u64) -> QuadraticLocal {
        let mut rng = Pcg64::new(seed);
        let mut b = Matrix::zeros(p, m);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let a = rng.normal_vec(m);
        QuadraticLocal::from_data(&b, &a, 0.05)
    }

    #[test]
    fn gradient_is_finite_difference() {
        let l = random_local(5, 12, 31);
        let mut rng = Pcg64::new(32);
        let theta = rng.normal_vec(5);
        let g = l.gradient(&theta);
        let h = 1e-6;
        for j in 0..5 {
            let mut tp = theta.clone();
            tp[j] += h;
            let mut tm = theta.clone();
            tm[j] -= h;
            let fd = (l.value(&tp) - l.value(&tm)) / (2.0 * h);
            assert!((g[j] - fd).abs() < 1e-4, "g[{j}]={} fd={fd}", g[j]);
        }
    }

    #[test]
    fn primal_recover_solves_stationarity() {
        let l = random_local(6, 15, 33);
        let mut rng = Pcg64::new(34);
        let v = rng.normal_vec(6);
        let theta = l.primal_recover(&v);
        // ∇f(θ) + v = 0.
        let g = l.gradient(&theta);
        for j in 0..6 {
            assert!((g[j] + v[j]).abs() < 1e-9, "{} vs {}", g[j], -v[j]);
        }
    }

    #[test]
    fn hess_vec_matches_hessian() {
        let l = random_local(4, 9, 35);
        let mut rng = Pcg64::new(36);
        let theta = rng.normal_vec(4);
        let z = rng.normal_vec(4);
        let hv = l.hess_vec(&theta, &z);
        let h = l.hessian(&theta);
        let hz = h.matvec(&z);
        for (a, b) in hv.iter().zip(&hz) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn value_nonnegative_for_least_squares() {
        // f(θ) = ‖a − Bᵀθ‖² + ridge ≥ 0.
        let l = random_local(3, 8, 37);
        let mut rng = Pcg64::new(38);
        for _ in 0..10 {
            let theta = rng.normal_vec(3);
            assert!(l.value(&theta) >= -1e-9);
        }
    }

    #[test]
    fn weighted_trajectories_match_manual() {
        let mut rng = Pcg64::new(39);
        let b1 = Matrix::from_rows(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let a1 = vec![1.0, 2.0];
        let b2 = Matrix::from_rows(2, 1, vec![1.0, 1.0]);
        let a2 = vec![3.0];
        let l = QuadraticLocal::from_weighted_trajectories(
            &[(b1, a1, 2.0), (b2, a2, 0.5)],
            0.0,
        );
        // P = 2·I + 0.5·[1;1][1,1]
        assert!((l.p_mat[(0, 0)] - 2.5).abs() < 1e-12);
        assert!((l.p_mat[(0, 1)] - 0.5).abs() < 1e-12);
        // c = 2·[1,2] + 0.5·3·[1,1] = [3.5, 5.5]
        assert!((l.c[0] - 3.5).abs() < 1e-12);
        assert!((l.c[1] - 5.5).abs() < 1e-12);
        let _ = rng.next_u64();
    }
}
