//! Consensus optimization problems (Section 3 + Appendix H).
//!
//! A [`ConsensusProblem`] is a set of per-node local objectives
//! `f_i : R^p → R`; the global task is
//! `min Σ f_i(x_i)  s.t.  x_1 = … = x_n` (Eq. 3). Appendix H's reductions
//! are implemented as concrete local objectives:
//!
//! - [`quadratic::QuadraticLocal`] — linear regression (H.1), London
//!   Schools, and RL reward-weighted regression (H.3), all of the form
//!   `θᵀP_iθ − 2c_iᵀθ + u_i`;
//! - [`logistic::LogisticLocal`] — logistic regression with L2 (H.2.1) or
//!   smoothed-L1 (H.2.2, Eq. 73) regularization.

pub mod quadratic;
pub mod logistic;
pub mod datasets;

use crate::linalg::cholesky::spd_solve;
use crate::linalg::Matrix;

/// Borrowed view of a local objective's data, used by the PJRT backend to
/// feed the AOT artifacts. `Opaque` objectives run native-only.
pub enum ExportData<'a> {
    /// Quadratic sufficient statistics (H.1/H.3): `P_i`, `c_i`.
    Quadratic { p_mat: &'a Matrix, c: &'a [f64] },
    /// Logistic raw data (H.2): features `B_i` (p × m_i, columns are
    /// examples), labels, regularization.
    Logistic { b: &'a Matrix, a: &'a [f64], mu: f64, reg: logistic::Reg },
    /// No exportable structure.
    Opaque,
}

/// A per-node local objective `f_i` with the oracles the algorithms need.
pub trait LocalObjective: Send + Sync {
    /// Feature dimension p.
    fn p(&self) -> usize;
    /// Objective value `f_i(θ)`.
    fn value(&self, theta: &[f64]) -> f64;
    /// Gradient `∇f_i(θ)`.
    fn gradient(&self, theta: &[f64]) -> Vec<f64>;
    /// Hessian `∇²f_i(θ)` (dense p×p).
    fn hessian(&self, theta: &[f64]) -> Matrix;
    /// Primal recovery (Eq. 6): `θ = argmin f_i(θ) + θᵀv`, i.e. solve
    /// `∇f_i(θ) = −v` for the Lagrangian-row input `v = (LΛ)(i,:)`.
    fn primal_recover(&self, v: &[f64]) -> Vec<f64>;
    /// Hessian-vector product (default: materialize the Hessian).
    fn hess_vec(&self, theta: &[f64], z: &[f64]) -> Vec<f64> {
        self.hessian(theta).matvec(z)
    }
    /// Data export for the PJRT artifacts (default: opaque → native only).
    fn export(&self) -> ExportData<'_> {
        ExportData::Opaque
    }
    /// Solve `(∇²f_i(θ) + shift·I) x = rhs` — the inner Newton system of
    /// primal recovery, ADMM and Network Newton. Default: dense Cholesky.
    /// Structured objectives override this with matrix-free solvers (the
    /// logistic local uses CG over `B D Bᵀ + diag`, which is what makes the
    /// m ≪ p fMRI regime tractable).
    fn solve_shifted(&self, theta: &[f64], rhs: &[f64], shift: f64) -> Vec<f64> {
        let mut h = self.hessian(theta);
        for i in 0..h.rows {
            h[(i, i)] += shift + 1e-12;
        }
        match crate::linalg::cholesky::Cholesky::factor(&h) {
            Ok(ch) => ch.solve(rhs),
            Err(_) => rhs.to_vec(),
        }
    }
}

/// The distributed problem: one local objective per graph node.
pub struct ConsensusProblem {
    /// Per-node objectives, indexed by node id.
    pub locals: Vec<Box<dyn LocalObjective>>,
    /// Feature dimension p (same for all nodes).
    pub p: usize,
}

impl ConsensusProblem {
    /// Bundle local objectives (validates equal dimensions).
    pub fn new(locals: Vec<Box<dyn LocalObjective>>) -> ConsensusProblem {
        assert!(!locals.is_empty());
        let p = locals[0].p();
        assert!(locals.iter().all(|l| l.p() == p), "mixed feature dims");
        ConsensusProblem { p, locals }
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.locals.len()
    }

    /// Global objective at a *stacked* per-node iterate θ (row-major n×p):
    /// `Σ_i f_i(θ_i)`.
    pub fn objective(&self, thetas: &[f64]) -> f64 {
        let p = self.p;
        assert_eq!(thetas.len(), self.n() * p);
        self.locals
            .iter()
            .enumerate()
            .map(|(i, l)| l.value(&thetas[i * p..(i + 1) * p]))
            .sum()
    }

    /// Global objective if every node held the same `θ`.
    pub fn objective_at(&self, theta: &[f64]) -> f64 {
        self.locals.iter().map(|l| l.value(theta)).sum()
    }

    /// Consensus error: `√(Σ_i ‖θ_i − θ̄‖²)` over the stacked iterate.
    pub fn consensus_error(&self, thetas: &[f64]) -> f64 {
        let (n, p) = (self.n(), self.p);
        let mut mean = vec![0.0; p];
        for i in 0..n {
            for j in 0..p {
                mean[j] += thetas[i * p + j];
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mut sq = 0.0;
        for i in 0..n {
            for j in 0..p {
                let d = thetas[i * p + j] - mean[j];
                sq += d * d;
            }
        }
        sq.sqrt()
    }

    /// Average iterate θ̄ across nodes.
    pub fn mean_iterate(&self, thetas: &[f64]) -> Vec<f64> {
        let (n, p) = (self.n(), self.p);
        let mut mean = vec![0.0; p];
        for i in 0..n {
            for j in 0..p {
                mean[j] += thetas[i * p + j] / n as f64;
            }
        }
        mean
    }

    /// Centralized optimum by (damped) Newton on `F(θ) = Σ f_i(θ)`.
    /// Returns `(θ*, F(θ*))`. Used only for reporting optimality gaps.
    pub fn centralized_optimum(&self, max_iter: usize, tol: f64) -> (Vec<f64>, f64) {
        let p = self.p;
        let mut theta = vec![0.0; p];
        for _ in 0..max_iter {
            let mut grad = vec![0.0; p];
            let mut hess = Matrix::zeros(p, p);
            for l in &self.locals {
                let g = l.gradient(&theta);
                for j in 0..p {
                    grad[j] += g[j];
                }
                hess.add_scaled(1.0, &l.hessian(&theta));
            }
            let gn = crate::linalg::vector::norm2(&grad);
            if gn < tol {
                break;
            }
            let step = spd_solve(&hess, &grad).expect("centralized Hessian SPD");
            // Backtracking line search on the global objective.
            let f0 = self.objective_at(&theta);
            let descent = crate::linalg::vector::dot(&grad, &step);
            let mut alpha = 1.0;
            loop {
                let cand: Vec<f64> =
                    theta.iter().zip(&step).map(|(t, s)| t - alpha * s).collect();
                if self.objective_at(&cand) <= f0 - 1e-4 * alpha * descent {
                    theta = cand;
                    break;
                }
                alpha *= 0.5;
                if alpha < 1e-12 {
                    theta = cand_at(&theta, &step, 1e-12);
                    break;
                }
            }
        }
        let f = self.objective_at(&theta);
        (theta, f)
    }
}

fn cand_at(theta: &[f64], step: &[f64], alpha: f64) -> Vec<f64> {
    theta.iter().zip(step).map(|(t, s)| t - alpha * s).collect()
}

/// Eigenvalue bounds (λ_min, λ_max) of a dense symmetric PSD matrix via
/// power iteration + spectral shift. Used to estimate Assumption 1's γ, Γ.
pub fn sym_eig_bounds(a: &Matrix, iters: usize) -> (f64, f64) {
    let n = a.rows;
    let mut rng = crate::util::Pcg64::new(0x5eed);
    // λ_max
    let mut v = rng.normal_vec(n);
    let mut lmax = 0.0;
    for _ in 0..iters {
        let y = a.matvec(&v);
        let ny = crate::linalg::vector::norm2(&y).max(1e-300);
        lmax = ny;
        for i in 0..n {
            v[i] = y[i] / ny;
        }
    }
    // λ_min via power iteration on (λ_max I − A)
    let mut w = rng.normal_vec(n);
    let mut shift_max = 0.0;
    for _ in 0..iters {
        let y = a.matvec(&w);
        let mut z = vec![0.0; n];
        for i in 0..n {
            z[i] = lmax * w[i] - y[i];
        }
        let nz = crate::linalg::vector::norm2(&z).max(1e-300);
        shift_max = nz;
        for i in 0..n {
            w[i] = z[i] / nz;
        }
    }
    ((lmax - shift_max).max(0.0), lmax)
}

/// Assumption-1 constants (γ, Γ) for a problem: extremal eigenvalues of the
/// local Hessians across nodes, evaluated at the given stacked iterate.
pub fn assumption1_bounds(problem: &ConsensusProblem, thetas: &[f64]) -> (f64, f64) {
    let p = problem.p;
    let mut gamma = f64::INFINITY;
    let mut big_gamma: f64 = 0.0;
    for (i, l) in problem.locals.iter().enumerate() {
        let h = l.hessian(&thetas[i * p..(i + 1) * p]);
        let (lo, hi) = sym_eig_bounds(&h, 60);
        gamma = gamma.min(lo);
        big_gamma = big_gamma.max(hi);
    }
    (gamma, big_gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use quadratic::QuadraticLocal;

    fn tiny_problem() -> ConsensusProblem {
        // Two nodes, p = 2; f_i(θ) = θᵀP_iθ − 2c_iᵀθ.
        let p1 = Matrix::from_rows(2, 2, vec![2.0, 0.0, 0.0, 1.0]);
        let p2 = Matrix::from_rows(2, 2, vec![1.0, 0.0, 0.0, 3.0]);
        let l1 = QuadraticLocal::new(p1, vec![1.0, 0.0], 0.0);
        let l2 = QuadraticLocal::new(p2, vec![0.0, 3.0], 0.0);
        ConsensusProblem::new(vec![Box::new(l1), Box::new(l2)])
    }

    #[test]
    fn objective_and_consensus_error() {
        let prob = tiny_problem();
        let thetas = vec![1.0, 0.0, 1.0, 0.0];
        assert!(prob.consensus_error(&thetas) < 1e-15);
        let thetas2 = vec![1.0, 0.0, 0.0, 0.0];
        assert!(prob.consensus_error(&thetas2) > 0.0);
        let f = prob.objective(&thetas);
        // f1(1,0) = 2 − 2 = 0 ; f2(1,0) = 1 − 0 = 1.
        assert!((f - 1.0).abs() < 1e-12);
    }

    #[test]
    fn centralized_optimum_quadratic() {
        let prob = tiny_problem();
        // Global: θᵀ(P1+P2)θ − 2(c1+c2)ᵀθ → θ* = (P1+P2)^{-1}(c1+c2) = [1/3, 3/4].
        let (theta, _) = prob.centralized_optimum(50, 1e-10);
        assert!((theta[0] - 1.0 / 3.0).abs() < 1e-8, "{theta:?}");
        assert!((theta[1] - 3.0 / 4.0).abs() < 1e-8, "{theta:?}");
    }

    #[test]
    fn eig_bounds_diagonal() {
        let a = Matrix::diag(&[1.0, 5.0, 9.0]);
        let (lo, hi) = sym_eig_bounds(&a, 200);
        assert!((hi - 9.0).abs() < 1e-6, "hi={hi}");
        assert!((lo - 1.0).abs() < 1e-4, "lo={lo}");
    }

    #[test]
    fn assumption1_bounds_quadratic() {
        let prob = tiny_problem();
        let thetas = vec![0.0; 4];
        let (g, gg) = assumption1_bounds(&prob, &thetas);
        // Hessians are 2P_i: eigenvalues {4,2} and {2,6}.
        assert!((g - 2.0).abs() < 1e-4, "gamma={g}");
        assert!((gg - 6.0).abs() < 1e-4, "Gamma={gg}");
    }
}
