//! Synthetic dataset generators matching the paper's benchmarks (see
//! DESIGN.md §5 for the substitution rationale).

use super::logistic::{sigmoid, LogisticLocal, Reg};
use super::quadratic::QuadraticLocal;
use super::ConsensusProblem;
use crate::dcp;
use crate::linalg::Matrix;
use crate::util::Pcg64;

/// Split `m_total` examples as evenly as possible over `n` nodes.
pub fn split_counts(m_total: usize, n: usize) -> Vec<usize> {
    let base = m_total / n;
    let extra = m_total % n;
    (0..n).map(|i| base + usize::from(i < extra)).collect()
}

/// Synthetic linear-regression consensus task (Fig. 1(a,b)):
/// `X ~ N(0,1)^{m×p}`, `y = Xθ* + ζ`, squared loss + ridge `μ` per node.
pub fn synthetic_regression(
    n_nodes: usize,
    p: usize,
    m_total: usize,
    noise: f64,
    mu: f64,
    rng: &mut Pcg64,
) -> ConsensusProblem {
    let theta_star = rng.normal_vec(p);
    let counts = split_counts(m_total, n_nodes);
    let mut locals: Vec<Box<dyn super::LocalObjective>> = Vec::with_capacity(n_nodes);
    for &mi in &counts {
        let mut b = Matrix::zeros(p, mi);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let a: Vec<f64> = (0..mi)
            .map(|j| {
                let z: f64 = (0..p).map(|i| b[(i, j)] * theta_star[i]).sum();
                z + noise * rng.normal()
            })
            .collect();
        locals.push(Box::new(QuadraticLocal::from_data(&b, &a, mu)));
    }
    ConsensusProblem::new(locals)
}

/// MNIST-like classification blobs (Fig. 1(c–f)): 10 Gaussian class
/// clusters in `p` dimensions (PCA-150 stand-in); one-vs-all binary task
/// for `target_class`.
pub fn mnist_like(
    n_nodes: usize,
    p: usize,
    m_total: usize,
    target_class: usize,
    reg: Reg,
    mu: f64,
    rng: &mut Pcg64,
) -> ConsensusProblem {
    let n_classes = 10;
    assert!(target_class < n_classes);
    // Class means on a sphere of radius 3.
    let means: Vec<Vec<f64>> = (0..n_classes)
        .map(|_| {
            let mut m = rng.normal_vec(p);
            let n2 = crate::linalg::vector::norm2(&m).max(1e-12);
            for v in m.iter_mut() {
                *v *= 3.0 / n2;
            }
            m
        })
        .collect();
    let counts = split_counts(m_total, n_nodes);
    let mut locals: Vec<Box<dyn super::LocalObjective>> = Vec::with_capacity(n_nodes);
    for &mi in &counts {
        let mut b = Matrix::zeros(p, mi);
        let mut a = Vec::with_capacity(mi);
        for j in 0..mi {
            let cls = rng.next_below(n_classes as u64) as usize;
            for i in 0..p {
                b[(i, j)] = means[cls][i] + rng.normal();
            }
            a.push(if cls == target_class { 1.0 } else { 0.0 });
        }
        locals.push(Box::new(LogisticLocal::new(b, a, mu, reg)));
    }
    ConsensusProblem::new(locals)
}

/// fMRI-like sparse task (Fig. 2(a,b)): very few samples (`m_total = 240`
/// in the paper), many features, k-sparse ground truth, L1-regularized
/// logistic loss. Preserves the m ≪ p regime.
pub fn fmri_like(
    n_nodes: usize,
    p: usize,
    m_total: usize,
    k_sparse: usize,
    alpha_smooth: f64,
    mu: f64,
    rng: &mut Pcg64,
) -> ConsensusProblem {
    let support = rng.sample_indices(p, k_sparse);
    let mut w = vec![0.0; p];
    for &s in &support {
        w[s] = rng.normal_ms(0.0, 2.0);
    }
    let counts = split_counts(m_total, n_nodes);
    let mut locals: Vec<Box<dyn super::LocalObjective>> = Vec::with_capacity(n_nodes);
    for &mi in &counts {
        let mut b = Matrix::zeros(p, mi);
        for v in b.data.iter_mut() {
            // Sparse-ish voxel activations: mostly small, occasional spikes.
            *v = if rng.bernoulli(0.1) { rng.normal_ms(0.0, 1.5) } else { 0.1 * rng.normal() };
        }
        let a: Vec<f64> = (0..mi)
            .map(|j| {
                let z: f64 = (0..p).map(|i| b[(i, j)] * w[i]).sum();
                f64::from(u8::from(rng.next_f64() < sigmoid(z)))
            })
            .collect();
        locals.push(Box::new(LogisticLocal::new(
            b,
            a,
            mu,
            Reg::SmoothL1 { alpha: alpha_smooth },
        )));
    }
    ConsensusProblem::new(locals)
}

/// London-Schools-like regression (Fig. 2(c,d), Fig. 3(a,b)): 139 school
/// blocks, 27 features per instance following [14]'s encoding — 4
/// school-specific + 3 student-specific categorical variables as binary
/// features, examination year, and a bias term. Scores are a linear
/// function of the encoding plus school-level effects and noise.
pub fn london_like(
    n_nodes: usize,
    m_total: usize,
    mu: f64,
    rng: &mut Pcg64,
) -> ConsensusProblem {
    let p = 27;
    let n_schools = 139;
    // Ground-truth weights + per-school intercept offsets.
    let w = rng.normal_vec(p);
    let school_effect: Vec<f64> = (0..n_schools).map(|_| rng.normal_ms(0.0, 0.5)).collect();
    // Categorical cardinalities for the 7 encoded variables (binary slots
    // summing to 25, plus year + bias = 27).
    let cards = [4usize, 3, 4, 4, 2, 4, 4];
    let counts = split_counts(m_total, n_nodes);
    let mut locals: Vec<Box<dyn super::LocalObjective>> = Vec::with_capacity(n_nodes);
    for &mi in &counts {
        let mut b = Matrix::zeros(p, mi);
        let mut a = Vec::with_capacity(mi);
        for j in 0..mi {
            let school = rng.next_below(n_schools as u64) as usize;
            let mut off = 0usize;
            for &c in &cards {
                let pick = rng.next_below(c as u64) as usize;
                b[(off + pick, j)] = 1.0;
                off += c;
            }
            b[(25, j)] = rng.uniform(0.0, 1.0); // normalized exam year
            b[(26, j)] = 1.0; // bias
            let z: f64 = (0..p).map(|i| b[(i, j)] * w[i]).sum();
            a.push(z + school_effect[school] + 0.3 * rng.normal());
        }
        locals.push(Box::new(QuadraticLocal::from_data(&b, &a, mu)));
    }
    ConsensusProblem::new(locals)
}

/// RL policy-search consensus task (Fig. 3(c,d)) from the DCP simulator:
/// rollouts are distributed across nodes; each node builds the
/// reward-weighted quadratic of Eq. 85/86.
pub fn rl_dcp(
    n_nodes: usize,
    rollouts: usize,
    t_len: usize,
    sigma: f64,
    mu: f64,
    rng: &mut Pcg64,
) -> ConsensusProblem {
    let params = dcp::DcpParams::default();
    let policy = dcp::behaviour_policy(sigma);
    let all = dcp::generate_rollouts(&params, &policy, rollouts, t_len, rng);
    let counts = split_counts(rollouts, n_nodes);
    let mut locals: Vec<Box<dyn super::LocalObjective>> = Vec::with_capacity(n_nodes);
    let mut idx = 0usize;
    for &mi in &counts {
        let trajs: Vec<(Matrix, Vec<f64>, f64)> = all[idx..idx + mi]
            .iter()
            .map(|r| (r.features.clone(), r.actions.clone(), r.reward))
            .collect();
        idx += mi;
        locals.push(Box::new(QuadraticLocal::from_weighted_trajectories(&trajs, mu)));
    }
    ConsensusProblem::new(locals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_counts_sums() {
        assert_eq!(split_counts(10, 3), vec![4, 3, 3]);
        assert_eq!(split_counts(9, 3), vec![3, 3, 3]);
        assert_eq!(split_counts(2, 3), vec![1, 1, 0]);
    }

    #[test]
    fn synthetic_regression_shapes() {
        let mut rng = Pcg64::new(61);
        let prob = synthetic_regression(5, 8, 100, 0.1, 0.05, &mut rng);
        assert_eq!(prob.n(), 5);
        assert_eq!(prob.p, 8);
        // Optimal value should be near the noise floor.
        let (_, f) = prob.centralized_optimum(50, 1e-9);
        assert!(f.is_finite());
    }

    #[test]
    fn mnist_like_learnable() {
        let mut rng = Pcg64::new(62);
        let prob = mnist_like(3, 10, 300, 0, Reg::L2, 0.01, &mut rng);
        let (theta, f_star) = prob.centralized_optimum(60, 1e-8);
        // Training loss at optimum must beat the trivial θ = 0 predictor.
        let f_zero = prob.objective_at(&vec![0.0; 10]);
        assert!(f_star < f_zero, "f*={f_star} f0={f_zero}");
        assert!(theta.iter().any(|v| v.abs() > 1e-3));
    }

    #[test]
    fn fmri_like_is_m_ll_p() {
        let mut rng = Pcg64::new(63);
        let prob = fmri_like(4, 64, 48, 8, 8.0, 0.02, &mut rng);
        assert_eq!(prob.p, 64);
        assert_eq!(prob.n(), 4);
        let f = prob.objective_at(&vec![0.0; 64]);
        assert!(f.is_finite() && f > 0.0);
    }

    #[test]
    fn london_like_has_27_features() {
        let mut rng = Pcg64::new(64);
        let prob = london_like(4, 200, 0.05, &mut rng);
        assert_eq!(prob.p, 27);
        let (_, f) = prob.centralized_optimum(30, 1e-8);
        assert!(f.is_finite());
    }

    #[test]
    fn rl_dcp_builds_quadratics() {
        let mut rng = Pcg64::new(65);
        let prob = rl_dcp(3, 12, 30, 0.5, 0.05, &mut rng);
        assert_eq!(prob.p, 6);
        assert_eq!(prob.n(), 3);
        let (theta, _) = prob.centralized_optimum(30, 1e-8);
        // Reward-weighted regression should produce a finite policy.
        assert!(theta.iter().all(|v| v.is_finite()));
    }
}
