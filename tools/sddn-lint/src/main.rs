//! Command-line front end for the sddn-lint invariant pass.
//!
//! Modes:
//! - no arguments: lint the enclosing repository (`rust/src` against the
//!   top-level `README.md`) — this is what CI runs;
//! - `--root DIR`: same, rooted at `DIR`;
//! - `--file F [--readme R]`: lint a single file (fixture mode). The
//!   forbidden-panic lint is always active in this mode, and env-var
//!   references resolve against `R` (nothing documented when omitted).
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or I/O error.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use sddn_lint::{lint_repo, lint_source, Violation};

fn usage() -> ExitCode {
    eprintln!("usage: sddn-lint [--root DIR | --file F [--readme R]]");
    ExitCode::from(2)
}

fn report(violations: &[Violation], scanned: Option<usize>) -> ExitCode {
    for v in violations {
        println!("{v}");
    }
    if violations.is_empty() {
        match scanned {
            Some(n) => println!("sddn-lint: {n} files clean"),
            None => println!("sddn-lint: clean"),
        }
        ExitCode::SUCCESS
    } else {
        println!("sddn-lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn run_repo(root: &Path) -> ExitCode {
    match lint_repo(root) {
        Ok(tree) => report(&tree.violations, Some(tree.files)),
        Err(e) => {
            eprintln!("sddn-lint: {e}");
            ExitCode::from(2)
        }
    }
}

fn run_file(file: &Path, readme: Option<&Path>) -> ExitCode {
    let src = match std::fs::read_to_string(file) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("sddn-lint: cannot read {}: {e}", file.display());
            return ExitCode::from(2);
        }
    };
    let readme = match readme {
        None => None,
        Some(p) => match std::fs::read_to_string(p) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("sddn-lint: cannot read {}: {e}", p.display());
                return ExitCode::from(2);
            }
        },
    };
    let label = file.to_string_lossy().replace('\\', "/");
    let violations = lint_source(&label, &src, true, readme.as_deref());
    report(&violations, None)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut file: Option<PathBuf> = None;
    let mut readme: Option<PathBuf> = None;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--root" | "--file" | "--readme" if i + 1 < args.len() => {
                let value = PathBuf::from(&args[i + 1]);
                match args[i].as_str() {
                    "--root" => root = Some(value),
                    "--file" => file = Some(value),
                    _ => readme = Some(value),
                }
                i += 2;
            }
            _ => return usage(),
        }
    }
    match (root, file) {
        (Some(_), Some(_)) => usage(),
        (None, Some(f)) => run_file(&f, readme.as_deref()),
        (Some(r), None) => run_repo(&r),
        (None, None) => {
            // The binary lives at <repo>/tools/sddn-lint; walk up to the
            // workspace root.
            let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
            match manifest.parent().and_then(Path::parent) {
                Some(repo) => run_repo(repo),
                None => usage(),
            }
        }
    }
}
