//! Repo-specific invariant lints for the sddnewton workspace.
//!
//! This crate is a zero-dependency static-analysis pass in the same
//! hand-rolled spirit as the main crate's `config::json` parser: a small
//! line-oriented scanner (comments, strings, and char literals are
//! stripped by an explicit state machine — no regexes, no syn) feeding
//! four source lints that encode invariants the runtime test suites can
//! only check on the schedules and inputs they happen to run:
//!
//! 1. **hot-path-alloc** — functions marked `// sddn-lint: hot-path`
//!    (the `*_ws` workspace variants and `step_impl` bodies) must not
//!    allocate per call: `Vec::new`, `vec!`, `.clone()` and `.collect`
//!    are forbidden inside them. `*_ws`/`step_impl` functions that are
//!    *not* marked are themselves violations (**missing-hot-path**), so
//!    new workspace variants cannot silently opt out.
//! 2. **forbidden-panic** — library modules (`net`, `sddm`, `linalg`,
//!    `algorithms`) must not `unwrap()`/`expect(`/`panic!` outside
//!    `#[cfg(test)]`; documented invariants are allowlisted with
//!    `// sddn-lint: allow(panic) reason=...`.
//! 3. **unregistered-overlay** — every `.exchange_apply(op, ...)` /
//!    `.exchange_apply_fresh(op, ...)` call site must either be marked
//!    `// sddn-lint: graph-support` (the operator's support provably
//!    stays within the graph halo) or be lexically paired with a
//!    `.register_plan(_, op)` on the same operator in the same file.
//! 4. **undocumented-env** — every `SDDN_*` environment variable named
//!    in a string literal must appear in the repo README.
//!
//! # Annotation grammar
//!
//! A directive is a line comment containing `sddn-lint:` followed by one
//! of:
//!
//! - `hot-path` — marks the next opened brace scope (place it directly
//!   above the `fn`) as a hot loop.
//! - `allow(alloc) reason=<text>` / `allow(panic) reason=<text>` /
//!   `allow(overlay) reason=<text>` — suppress the corresponding lint on
//!   the directive's own line and the line directly below it. The reason
//!   is mandatory and must be non-empty.
//! - `graph-support` — asserts the operator of an exchange call on this
//!   or the next line has graph support (an optional trailing note is
//!   allowed).
//!
//! Coverage is deliberately tight (one line), so an allowlist entry
//! cannot drift away from the code it excuses.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// The lint kinds this pass enforces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lint {
    /// Allocation token inside a `hot-path` scope.
    HotPathAlloc,
    /// A `*_ws`/`step_impl` function without a `hot-path` marker.
    MissingHotPath,
    /// `unwrap()`/`expect(`/`panic!` in a library module outside tests.
    ForbiddenPanic,
    /// `exchange_apply` on an operator with no `register_plan` pairing
    /// and no `graph-support` annotation.
    UnregisteredOverlay,
    /// `SDDN_*` env var referenced in code but absent from the README.
    UndocumentedEnv,
    /// A `sddn-lint:` comment that does not parse (e.g. `allow` without
    /// a reason).
    MalformedDirective,
}

impl Lint {
    /// Stable kebab-case key used in reports.
    pub fn key(&self) -> &'static str {
        match self {
            Lint::HotPathAlloc => "hot-path-alloc",
            Lint::MissingHotPath => "missing-hot-path",
            Lint::ForbiddenPanic => "forbidden-panic",
            Lint::UnregisteredOverlay => "unregistered-overlay",
            Lint::UndocumentedEnv => "undocumented-env",
            Lint::MalformedDirective => "malformed-directive",
        }
    }
}

/// One lint violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path label of the offending file (repo-relative in tree mode).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Which lint fired.
    pub lint: Lint,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint.key(), self.msg)
    }
}

/// Allocation tokens forbidden inside `hot-path` scopes.
const HOT_TOKENS: &[&str] = &["Vec::new", "vec!", ".clone()", ".collect"];

/// Panic-family tokens forbidden in library modules outside tests.
const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// Library module directories (under `rust/src`) the panic lint covers.
const PANIC_SCOPE_DIRS: &[&str] = &["net", "sddm", "linalg", "algorithms"];

/// What one `sddn-lint:` comment grants.
#[derive(Debug, Clone, Copy, Default)]
struct Directive {
    hot_path: bool,
    allow_alloc: bool,
    allow_panic: bool,
    allow_overlay: bool,
}

/// Parse the text after `sddn-lint:`. Returns the grants, or an error
/// message for a directive that does not follow the grammar.
fn parse_directive(text: &str) -> Result<Directive, String> {
    let mut d = Directive::default();
    let text = text.trim();
    let head = text.split_whitespace().next().unwrap_or("");
    match head {
        "hot-path" => d.hot_path = true,
        "graph-support" => d.allow_overlay = true,
        "allow(alloc)" | "allow(panic)" | "allow(overlay)" => {
            let rest = text[head.len()..].trim();
            let reason = rest.strip_prefix("reason=").map(str::trim).unwrap_or("");
            if reason.is_empty() {
                return Err(format!("`{head}` requires a non-empty `reason=<text>`"));
            }
            match head {
                "allow(alloc)" => d.allow_alloc = true,
                "allow(panic)" => d.allow_panic = true,
                _ => d.allow_overlay = true,
            }
        }
        _ => return Err(format!("unknown directive `{text}`")),
    }
    Ok(d)
}

/// One source line after lexical classification.
struct LineScan {
    /// The line with comments and literal contents blanked out (string
    /// quotes are kept, so `.expect("` still contains `.expect(`).
    code: String,
    /// Contents of string literals on this line (for the env-var lint).
    strings: String,
    /// Raw text after `sddn-lint:` when the line carries a directive.
    directive: Option<String>,
}

/// Cross-line lexer state.
enum Mode {
    Code,
    Block(u32),
    Str,
    RawStr(u32),
}

fn last_is_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Split a source file into [`LineScan`]s, tracking multi-line comments
/// and strings across line boundaries.
fn classify_lines(src: &str) -> Vec<LineScan> {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    for raw in src.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut strings = String::new();
        let mut directive = None;
        let mut i = 0usize;
        while i < chars.len() {
            match mode {
                Mode::Block(depth) => {
                    if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        i += 2;
                        mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        i += 2;
                        mode = Mode::Block(depth + 1);
                    } else {
                        i += 1;
                    }
                }
                Mode::Str => {
                    if chars[i] == '\\' {
                        i += 2;
                    } else if chars[i] == '"' {
                        code.push('"');
                        i += 1;
                        mode = Mode::Code;
                    } else {
                        strings.push(chars[i]);
                        i += 1;
                    }
                }
                Mode::RawStr(h) => {
                    let closes = chars[i] == '"'
                        && (0..h as usize).all(|d| chars.get(i + 1 + d) == Some(&'#'));
                    if closes {
                        code.push('"');
                        i += 1 + h as usize;
                        mode = Mode::Code;
                    } else {
                        strings.push(chars[i]);
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        let comment: String = chars[i..].iter().collect();
                        if let Some(p) = comment.find("sddn-lint:") {
                            directive =
                                Some(comment[p + "sddn-lint:".len()..].trim().to_string());
                        }
                        break;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        code.push(' ');
                        i += 2;
                        mode = Mode::Block(1);
                    } else if c == '"' {
                        code.push('"');
                        i += 1;
                        mode = Mode::Str;
                    } else if (c == 'r' || c == 'b') && !last_is_ident(&code) {
                        // Possible raw/byte string: r", r#", br", b".
                        let mut j = i;
                        if chars[j] == 'b' {
                            j += 1;
                        }
                        let has_r = chars.get(j) == Some(&'r');
                        if has_r {
                            j += 1;
                        }
                        let mut hashes = 0u32;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        let opens = chars.get(j) == Some(&'"') && (has_r || hashes == 0);
                        if opens && (has_r || c == 'b') {
                            code.push('"');
                            i = j + 1;
                            mode = if has_r { Mode::RawStr(hashes) } else { Mode::Str };
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == '\'' {
                        // Char literal vs lifetime.
                        if chars.get(i + 1) == Some(&'\\') {
                            let mut j = i + 3;
                            if chars.get(i + 2) == Some(&'u') && chars.get(i + 3) == Some(&'{') {
                                j = i + 4;
                                while j < chars.len() && chars[j] != '}' {
                                    j += 1;
                                }
                                j += 1;
                            }
                            if chars.get(j) == Some(&'\'') {
                                j += 1;
                            }
                            code.push(' ');
                            i = j;
                        } else if chars.get(i + 2) == Some(&'\'') {
                            code.push(' ');
                            i += 3;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        strings.push(' ');
        out.push(LineScan { code, strings, directive });
    }
    out
}

/// Per-line scope flags from the brace walk.
struct ScopeMap {
    /// Line is (at least partly) inside a `#[cfg(test)]` scope.
    test: Vec<bool>,
    /// Line is (at least partly) inside a `hot-path` scope.
    hot: Vec<bool>,
}

fn is_hot_fn_name(name: &str) -> bool {
    name.ends_with("_ws") || name == "step_impl"
}

/// Walk brace scopes: track `#[cfg(test)]` and `hot-path` regions and
/// flag `*_ws`/`step_impl` bodies that open without a hot-path marker.
fn walk_scopes(
    label: &str,
    lines: &[LineScan],
    directives: &[Directive],
    violations: &mut Vec<Violation>,
) -> ScopeMap {
    #[derive(Clone, Copy)]
    struct Flags {
        test: bool,
        hot: bool,
    }
    let mut stack: Vec<Flags> = Vec::new();
    let mut cur = Flags { test: false, hot: false };
    let mut pending_test = false;
    let mut pending_hot = false;
    let mut pending_fn: Option<(String, usize)> = None;
    let mut paren_depth: i64 = 0;
    let mut test_any = vec![false; lines.len()];
    let mut hot_any = vec![false; lines.len()];

    for (idx, line) in lines.iter().enumerate() {
        if directives[idx].hot_path {
            pending_hot = true;
        }
        if line.code.contains("cfg(test)") {
            pending_test = true;
        }
        test_any[idx] = cur.test;
        hot_any[idx] = cur.hot;
        let chars: Vec<char> = line.code.chars().collect();
        let mut i = 0usize;
        let mut prev_ident = false;
        while i < chars.len() {
            let c = chars[i];
            if (c.is_alphabetic() || c == '_') && !prev_ident {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                let word: String = chars[start..i].iter().collect();
                if word == "fn" {
                    let mut j = i;
                    while j < chars.len() && chars[j].is_whitespace() {
                        j += 1;
                    }
                    let ns = j;
                    while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_') {
                        j += 1;
                    }
                    let name: String = chars[ns..j].iter().collect();
                    if is_hot_fn_name(&name) && !cur.test && !pending_test {
                        pending_fn = Some((name, idx));
                    }
                }
                prev_ident = true;
                continue;
            }
            prev_ident = c.is_alphanumeric() || c == '_';
            match c {
                '(' => paren_depth += 1,
                ')' => paren_depth -= 1,
                ';' if paren_depth == 0 => pending_fn = None,
                '{' => {
                    let next = Flags {
                        test: cur.test || pending_test,
                        hot: cur.hot || pending_hot,
                    };
                    if let Some((name, fline)) = pending_fn.take() {
                        if !next.hot && !next.test {
                            violations.push(Violation {
                                file: label.to_string(),
                                line: fline + 1,
                                lint: Lint::MissingHotPath,
                                msg: format!(
                                    "`fn {name}` is a hot-loop body (`*_ws`/`step_impl`) but \
                                     is not marked `// sddn-lint: hot-path`"
                                ),
                            });
                        }
                    }
                    pending_test = false;
                    pending_hot = false;
                    stack.push(cur);
                    cur = next;
                    test_any[idx] |= cur.test;
                    hot_any[idx] |= cur.hot;
                }
                '}' => {
                    if let Some(prev) = stack.pop() {
                        cur = prev;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        test_any[idx] |= cur.test;
        hot_any[idx] |= cur.hot;
    }
    ScopeMap { test: test_any, hot: hot_any }
}

/// Find every occurrence of `needle` in `hay`.
fn find_all(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(needle) {
        out.push(from + p);
        from += p + needle.len();
    }
    out
}

/// Normalize a call-site operand for pairing comparison: drop leading
/// `&`/`mut` and all whitespace, so `&self.x` written across lines still
/// matches the `x` handed to `register_plan`.
fn normalize_operand(arg: &str) -> String {
    let s = arg.trim();
    let s = s.strip_prefix('&').unwrap_or(s).trim_start();
    let s = s.strip_prefix("mut ").unwrap_or(s);
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

/// Extract the argument starting at `start` (just past the opening paren
/// or a comma), up to the next top-level comma or the closing paren.
fn extract_arg(full: &str, start: usize) -> (String, usize) {
    let mut depth = 1i64;
    let mut arg = String::new();
    let mut end = full.len();
    for (off, ch) in full[start..].char_indices() {
        match ch {
            '(' | '[' | '{' => {
                depth += 1;
                arg.push(ch);
            }
            ')' | ']' | '}' => {
                depth -= 1;
                if depth == 0 {
                    end = start + off;
                    break;
                }
                arg.push(ch);
            }
            ',' if depth == 1 => {
                end = start + off;
                break;
            }
            _ => arg.push(ch),
        }
    }
    (arg, end)
}

/// Scan result for one file.
pub struct FileReport {
    /// Violations found in this file (env-var refs not yet resolved).
    pub violations: Vec<Violation>,
    /// `SDDN_*` variables referenced in string literals: `(name, line)`.
    pub env_refs: Vec<(String, usize)>,
}

/// Run the scoped lints over one source file. `panic_scope` controls
/// whether the forbidden-panic lint applies (library modules only in
/// tree mode; always on for single-file fixture runs).
pub fn scan_file(label: &str, src: &str, panic_scope: bool) -> FileReport {
    let lines = classify_lines(src);
    let mut violations = Vec::new();
    let mut directives = Vec::with_capacity(lines.len());
    for (idx, line) in lines.iter().enumerate() {
        match &line.directive {
            None => directives.push(Directive::default()),
            Some(text) => match parse_directive(text) {
                Ok(d) => directives.push(d),
                Err(msg) => {
                    directives.push(Directive::default());
                    violations.push(Violation {
                        file: label.to_string(),
                        line: idx + 1,
                        lint: Lint::MalformedDirective,
                        msg,
                    });
                }
            },
        }
    }
    let scope = walk_scopes(label, &lines, &directives, &mut violations);
    let covered = |idx: usize, pick: fn(&Directive) -> bool| -> bool {
        pick(&directives[idx]) || (idx > 0 && pick(&directives[idx - 1]))
    };

    // Lint 1: allocation tokens inside hot-path scopes.
    for (idx, line) in lines.iter().enumerate() {
        if !scope.hot[idx] || scope.test[idx] {
            continue;
        }
        for tok in HOT_TOKENS {
            for _ in find_all(&line.code, tok) {
                if covered(idx, |d| d.allow_alloc) {
                    continue;
                }
                violations.push(Violation {
                    file: label.to_string(),
                    line: idx + 1,
                    lint: Lint::HotPathAlloc,
                    msg: format!(
                        "`{tok}` inside a hot-path fn (annotate \
                         `// sddn-lint: allow(alloc) reason=...` if intentional)"
                    ),
                });
            }
        }
    }

    // Lint 2: panic-family tokens in library modules outside tests.
    if panic_scope {
        for (idx, line) in lines.iter().enumerate() {
            if scope.test[idx] {
                continue;
            }
            for tok in PANIC_TOKENS {
                for _ in find_all(&line.code, tok) {
                    if covered(idx, |d| d.allow_panic) {
                        continue;
                    }
                    violations.push(Violation {
                        file: label.to_string(),
                        line: idx + 1,
                        lint: Lint::ForbiddenPanic,
                        msg: format!(
                            "`{tok}` in a library module (return the hand-rolled error \
                             type, or annotate `// sddn-lint: allow(panic) reason=...` \
                             for a documented invariant)"
                        ),
                    });
                }
            }
        }
    }

    // Lint 3: exchange_apply operators must have graph support or a
    // lexical register_plan pairing in the same file.
    let mut full = String::new();
    let mut line_start = Vec::with_capacity(lines.len());
    for line in &lines {
        line_start.push(full.len());
        full.push_str(&line.code);
        full.push('\n');
    }
    let line_of = |pos: usize| -> usize {
        match line_start.binary_search(&pos) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    };
    let mut registered: Vec<String> = Vec::new();
    for pos in find_all(&full, ".register_plan(") {
        if scope.test[line_of(pos)] {
            continue;
        }
        let args_at = pos + ".register_plan(".len();
        let (_, first_end) = extract_arg(&full, args_at);
        if full[first_end..].starts_with(',') {
            let (second, _) = extract_arg(&full, first_end + 1);
            registered.push(normalize_operand(&second));
        }
    }
    for pos in find_all(&full, ".exchange_apply") {
        let after = &full[pos + ".exchange_apply".len()..];
        let args_at = if after.starts_with('(') {
            pos + ".exchange_apply(".len()
        } else if after.starts_with("_fresh(") {
            pos + ".exchange_apply_fresh(".len()
        } else {
            continue;
        };
        let idx = line_of(pos);
        if scope.test[idx] || covered(idx, |d| d.allow_overlay) {
            continue;
        }
        let (first, _) = extract_arg(&full, args_at);
        let operand = normalize_operand(&first);
        if registered.contains(&operand) {
            continue;
        }
        violations.push(Violation {
            file: label.to_string(),
            line: idx + 1,
            lint: Lint::UnregisteredOverlay,
            msg: format!(
                "exchange on operator `{operand}` has no `register_plan` pairing in this \
                 file; annotate `// sddn-lint: graph-support` if its support stays within \
                 the graph halo"
            ),
        });
    }

    // Lint 4 (collection only): SDDN_* env vars in string literals.
    let mut env_refs = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        for pos in find_all(&line.strings, "SDDN_") {
            let var: String = line.strings[pos..]
                .chars()
                .take_while(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || *c == '_')
                .collect();
            env_refs.push((var, idx + 1));
        }
    }
    FileReport { violations, env_refs }
}

/// Lint one source string end to end, resolving env-var references
/// against an optional README text (absent README = nothing documented).
pub fn lint_source(
    label: &str,
    src: &str,
    panic_scope: bool,
    readme: Option<&str>,
) -> Vec<Violation> {
    let report = scan_file(label, src, panic_scope);
    let mut violations = report.violations;
    let mut seen: Vec<String> = Vec::new();
    for (var, line) in report.env_refs {
        if seen.contains(&var) {
            continue;
        }
        seen.push(var.clone());
        if readme.is_some_and(|r| r.contains(&var)) {
            continue;
        }
        violations.push(Violation {
            file: label.to_string(),
            line,
            lint: Lint::UndocumentedEnv,
            msg: format!("env var `{var}` is referenced in code but not documented in README.md"),
        });
    }
    violations
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Result of a whole-tree lint run.
pub struct TreeReport {
    /// All violations, in path order.
    pub violations: Vec<Violation>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

/// Lint every `.rs` file under `src_root`, resolving env references
/// against `readme`. The forbidden-panic lint applies to files whose
/// first path component under `src_root` is a library module directory.
pub fn lint_tree(src_root: &Path, readme: &str) -> Result<TreeReport, String> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let rel = path.strip_prefix(src_root).unwrap_or(path);
        let label = rel.to_string_lossy().replace('\\', "/");
        let panic_scope = rel
            .components()
            .next()
            .map(|c| PANIC_SCOPE_DIRS.contains(&c.as_os_str().to_string_lossy().as_ref()))
            .unwrap_or(false);
        let src = fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        violations.extend(lint_source(&label, &src, panic_scope, Some(readme)));
    }
    Ok(TreeReport { violations, files: files.len() })
}

/// Lint the repository rooted at `root`: walks `rust/src` and resolves
/// env references against the top-level `README.md`.
pub fn lint_repo(root: &Path) -> Result<TreeReport, String> {
    let src_root = root.join("rust").join("src");
    let readme_path = root.join("README.md");
    let readme = fs::read_to_string(&readme_path)
        .map_err(|e| format!("cannot read {}: {e}", readme_path.display()))?;
    lint_tree(&src_root, &readme)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(vs: &[Violation]) -> Vec<Lint> {
        vs.iter().map(|v| v.lint).collect()
    }

    #[test]
    fn comments_and_strings_are_blanked() {
        let mut src = String::new();
        src.push_str("fn f() {\n");
        src.push_str("    let s = \"panic!(no) .unwrap()\";\n");
        src.push_str("    // .unwrap() in a comment\n");
        src.push_str("    /* .expect( in a block comment */\n");
        src.push_str("    let c = '\"';\n");
        src.push_str("    let r = r#\".unwrap()\"#;\n");
        src.push_str("}\n");
        let vs = lint_source("t.rs", &src, true, None);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn panic_fires_outside_tests_only() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n\
                   #[cfg(test)]\nmod tests {\n    fn g(x: Option<u32>) -> u32 {\n        \
                   x.unwrap()\n    }\n}\n";
        let vs = lint_source("t.rs", src, true, None);
        assert_eq!(kinds(&vs), vec![Lint::ForbiddenPanic]);
        assert_eq!(vs[0].line, 2);
    }

    #[test]
    fn allow_panic_requires_reason() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // sddn-lint: allow(panic)\n    \
                   x.unwrap()\n}\n";
        let vs = lint_source("t.rs", src, true, None);
        assert!(kinds(&vs).contains(&Lint::MalformedDirective), "{vs:?}");
        assert!(kinds(&vs).contains(&Lint::ForbiddenPanic), "{vs:?}");
        let ok = "fn f(x: Option<u32>) -> u32 {\n    // sddn-lint: allow(panic) reason=infallible\n    \
                  x.unwrap()\n}\n";
        assert!(lint_source("t.rs", ok, true, None).is_empty());
    }

    #[test]
    fn hot_path_allocs_fire_and_unmarked_ws_fn_fires() {
        let src = "// sddn-lint: hot-path\nfn solve_ws(n: usize) -> Vec<f64> {\n    \
                   let v = vec![0.0; n];\n    v\n}\n";
        let vs = lint_source("t.rs", src, false, None);
        assert_eq!(kinds(&vs), vec![Lint::HotPathAlloc]);
        let unmarked = "fn step_impl(n: usize) -> usize {\n    n\n}\n";
        let vs = lint_source("t.rs", unmarked, false, None);
        assert_eq!(kinds(&vs), vec![Lint::MissingHotPath]);
    }

    #[test]
    fn trait_decl_without_body_needs_no_marker() {
        let src = "trait S {\n    fn solve_ws(&self, n: usize) -> usize;\n}\n";
        assert!(lint_source("t.rs", src, false, None).is_empty());
    }

    #[test]
    fn overlay_pairing_and_annotation() {
        let fires = "fn f(e: &mut dyn E, op: &Csr) {\n    e.exchange_apply(op, 1, x, 1, y);\n}\n";
        let vs = lint_source("t.rs", fires, false, None);
        assert_eq!(kinds(&vs), vec![Lint::UnregisteredOverlay]);
        let paired = "fn f(e: &mut dyn E, op: &Csr) {\n    e.register_plan(\"lvl\", op);\n    \
                      e.exchange_apply(op, 1, x, 1, y);\n}\n";
        assert!(lint_source("t.rs", paired, false, None).is_empty());
        let noted = "fn f(e: &mut dyn E, op: &Csr) {\n    // sddn-lint: graph-support\n    \
                     e.exchange_apply(op, 1, x, 1, y);\n}\n";
        assert!(lint_source("t.rs", noted, false, None).is_empty());
    }

    #[test]
    fn multiline_operand_matches_register_pairing() {
        let src = "fn f(e: &mut dyn E, s: &S) {\n    e.register_plan(\"lvl\", &s.op);\n    \
                   e.exchange_apply(\n        &s.op,\n        1,\n        x,\n        1,\n        \
                   y,\n    );\n}\n";
        assert!(lint_source("t.rs", src, false, None).is_empty(), "multiline pairing");
    }

    #[test]
    fn env_vars_resolve_against_readme() {
        let src = "fn f() -> Option<String> {\n    std::env::var(\"SDDN_KNOB\").ok()\n}\n";
        let vs = lint_source("t.rs", src, false, None);
        assert_eq!(kinds(&vs), vec![Lint::UndocumentedEnv]);
        let vs = lint_source("t.rs", src, false, Some("docs: `SDDN_KNOB` sets the knob"));
        assert!(vs.is_empty());
    }
}
