// Fixture: socket-transport code must return the typed error, not panic —
// the forbidden-panic lint covers `net/tcp` like any other net module.

use std::io::Read;
use std::net::TcpStream;

fn dial(addr: &str) -> TcpStream {
    TcpStream::connect(addr).unwrap() // fires: .unwrap()
}

fn read_header(stream: &mut TcpStream) -> [u8; 16] {
    let mut head = [0u8; 16];
    stream.read_exact(&mut head).expect("peer sent a full header"); // fires: .expect(
    head
}

fn reject(kind: u8) -> ! {
    panic!("unexpected frame kind {kind}") // fires: panic!(
}
