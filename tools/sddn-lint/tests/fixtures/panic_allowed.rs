// Fixture: reason-carrying allowlists and #[cfg(test)] scopes suppress
// the forbidden-panic lint.

fn pick(values: &[f64], at: Option<usize>) -> f64 {
    // sddn-lint: allow(panic) reason=caller guarantees at is Some by construction
    let i = at.unwrap();
    values[i]
}

fn fallible(values: &[f64]) -> Result<f64, String> {
    values.first().copied().ok_or_else(|| "empty".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(pick(&[1.0], Some(0)), 1.0);
        fallible(&[2.0]).unwrap();
    }
}
