// Fixture: an exchange on an operator with no register_plan pairing and
// no graph-support annotation must fire.

fn apply(exch: &mut dyn Exchange, overlay: &Csr, x: &[f64], out: &mut [f64]) {
    exch.exchange_apply(overlay, 0, x, 1, out); // fires: unregistered overlay
    exch.exchange_apply_fresh(&overlay, 0, x, 1, out, true); // fires too
}
