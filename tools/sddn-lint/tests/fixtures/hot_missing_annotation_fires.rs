// Fixture: a `*_ws` body without the hot-path marker must fire, even if
// it does not allocate; trait declarations without bodies are exempt.

trait Solver {
    fn solve_ws(&self, n: usize) -> usize;
}

fn crude_solve_ws(n: usize) -> usize {
    n + 1
}
