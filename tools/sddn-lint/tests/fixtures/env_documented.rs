// Fixture: an SDDN_* env var that the README documents is clean.

fn threads() -> Option<usize> {
    std::env::var("SDDN_FIXTURE_THREADS").ok()?.parse().ok()
}
