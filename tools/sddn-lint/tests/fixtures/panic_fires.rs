// Fixture: panic-family tokens in library code outside tests must fire.

fn pick(values: &[f64], at: Option<usize>) -> f64 {
    let i = at.unwrap(); // fires: .unwrap()
    if i >= values.len() {
        panic!("index {i} out of range"); // fires: panic!(
    }
    values.get(i).copied().expect("checked above") // fires: .expect(
}
