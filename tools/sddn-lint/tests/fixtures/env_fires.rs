// Fixture: an SDDN_* env var referenced in code but absent from the
// README must fire.

fn knob() -> Option<usize> {
    std::env::var("SDDN_SECRET_KNOB").ok()?.parse().ok()
}
