// Fixture: allow(alloc) with a reason suppresses the hot-path lint, and
// helper functions outside the hot scope may allocate freely.

// sddn-lint: hot-path
fn solve_ws(n: usize, pool: &mut BufferPool) -> Vec<f64> {
    // sddn-lint: allow(alloc) reason=one-time lazy growth, reused across calls
    let v = vec![0.0; n];
    let w = pool.take(n);
    pool.put(w);
    v
}

fn setup(n: usize) -> Vec<f64> {
    let mut v = Vec::new();
    v.resize(n, 0.0);
    v.clone()
}
