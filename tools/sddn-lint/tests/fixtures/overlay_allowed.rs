// Fixture: both accepted forms — a lexical register_plan pairing on the
// same operator, and a graph-support annotation for operators whose
// support provably stays within the halo.

fn apply_registered(exch: &mut dyn Exchange, level: &Level, x: &[f64], out: &mut [f64]) {
    exch.register_plan("chain level", &level.overlay);
    exch.exchange_apply(&level.overlay, level.offdiag, x, 1, out);
}

fn apply_graph_support(exch: &mut dyn Exchange, lap: &Csr, x: &[f64], out: &mut [f64]) {
    // sddn-lint: graph-support Laplacian sparsity is exactly the comm graph
    exch.exchange_apply(lap, 0, x, 1, out);
}
