// Fixture: the TCP transport idiom — typed errors on the socket path, a
// reason-carrying allowlist on the one unrecoverable death, and
// README-documented SDDN_TCP_* tuning knobs.

fn timeout_ms() -> u64 {
    std::env::var("SDDN_TCP_TIMEOUT_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(30_000)
}

fn retries() -> u32 {
    std::env::var("SDDN_TCP_RETRIES").ok().and_then(|v| v.parse().ok()).unwrap_or(40)
}

fn backoff_ms() -> u64 {
    std::env::var("SDDN_TCP_RETRY_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(50)
}

fn read_exact_or_err(buf: &[u8], want: usize) -> Result<&[u8], String> {
    buf.get(..want).ok_or_else(|| format!("short read: {} of {want} bytes", buf.len()))
}

fn die(rank: usize, err: String) -> ! {
    // sddn-lint: allow(panic) reason=socket failure mid-round is unrecoverable under the Exchange contract
    panic!("tcp transport rank {rank}: {err}")
}
