// Fixture: allocation tokens inside a hot-path scope must fire.

// sddn-lint: hot-path
fn solve_ws(n: usize, src: &[f64]) -> Vec<f64> {
    let mut v = vec![0.0; n]; // fires: vec!
    let w = Vec::new(); // fires: Vec::new
    let c = src.to_vec().clone(); // fires: .clone()
    let s: Vec<f64> = src.iter().copied().collect(); // fires: .collect
    v.extend_from_slice(&c);
    v.extend_from_slice(&s);
    let _ = w;
    v
}
