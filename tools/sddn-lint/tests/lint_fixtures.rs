//! Fixture tests for the sddn-lint pass: each lint exercised in both a
//! firing and an allowlisted variant, the CLI exit-code contract, and a
//! `repo_is_clean` gate that runs the full lint over the enclosing
//! repository (so `cargo test` fails whenever `cargo run -p sddn-lint`
//! would).

use std::path::{Path, PathBuf};
use std::process::Command;

use sddn_lint::{lint_repo, lint_source, Lint, Violation};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(name)
}

fn lint_fixture(name: &str, readme: Option<&str>) -> Vec<Violation> {
    let src = std::fs::read_to_string(fixture(name)).unwrap();
    let readme = readme.map(|r| std::fs::read_to_string(fixture(r)).unwrap());
    lint_source(name, &src, true, readme.as_deref())
}

fn kinds(vs: &[Violation]) -> Vec<Lint> {
    vs.iter().map(|v| v.lint).collect()
}

/// Run the CLI in `--file` fixture mode and return its exit code.
fn run_cli(name: &str, readme: Option<&str>) -> i32 {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sddn-lint"));
    cmd.arg("--file").arg(fixture(name));
    if let Some(r) = readme {
        cmd.arg("--readme").arg(fixture(r));
    }
    cmd.status().unwrap().code().unwrap()
}

#[test]
fn hot_alloc_fires() {
    let vs = lint_fixture("hot_alloc_fires.rs", None);
    assert_eq!(vs.len(), 4, "{vs:?}");
    assert!(kinds(&vs).iter().all(|k| *k == Lint::HotPathAlloc), "{vs:?}");
    assert_eq!(run_cli("hot_alloc_fires.rs", None), 1);
}

#[test]
fn hot_alloc_allowed() {
    let vs = lint_fixture("hot_alloc_allowed.rs", None);
    assert!(vs.is_empty(), "{vs:?}");
    assert_eq!(run_cli("hot_alloc_allowed.rs", None), 0);
}

#[test]
fn hot_missing_annotation_fires() {
    let vs = lint_fixture("hot_missing_annotation_fires.rs", None);
    assert_eq!(kinds(&vs), vec![Lint::MissingHotPath], "{vs:?}");
    assert_eq!(run_cli("hot_missing_annotation_fires.rs", None), 1);
}

#[test]
fn panic_fires() {
    let vs = lint_fixture("panic_fires.rs", None);
    assert_eq!(vs.len(), 3, "{vs:?}");
    assert!(kinds(&vs).iter().all(|k| *k == Lint::ForbiddenPanic), "{vs:?}");
    assert_eq!(run_cli("panic_fires.rs", None), 1);
}

#[test]
fn panic_allowed() {
    let vs = lint_fixture("panic_allowed.rs", None);
    assert!(vs.is_empty(), "{vs:?}");
    assert_eq!(run_cli("panic_allowed.rs", None), 0);
}

#[test]
fn overlay_fires() {
    let vs = lint_fixture("overlay_fires.rs", None);
    assert_eq!(vs.len(), 2, "{vs:?}");
    assert!(kinds(&vs).iter().all(|k| *k == Lint::UnregisteredOverlay), "{vs:?}");
    assert_eq!(run_cli("overlay_fires.rs", None), 1);
}

#[test]
fn overlay_allowed() {
    let vs = lint_fixture("overlay_allowed.rs", None);
    assert!(vs.is_empty(), "{vs:?}");
    assert_eq!(run_cli("overlay_allowed.rs", None), 0);
}

#[test]
fn env_fires_without_readme_entry() {
    let vs = lint_fixture("env_fires.rs", None);
    assert_eq!(kinds(&vs), vec![Lint::UndocumentedEnv], "{vs:?}");
    assert_eq!(run_cli("env_fires.rs", None), 1);
    // Documenting the var in the readme is also a valid fix.
    let vs = lint_fixture("env_fires.rs", Some("README_env.md"));
    assert_eq!(kinds(&vs), vec![Lint::UndocumentedEnv], "not this readme");
}

#[test]
fn env_documented_is_clean() {
    let vs = lint_fixture("env_documented.rs", Some("README_env.md"));
    assert!(vs.is_empty(), "{vs:?}");
    assert_eq!(run_cli("env_documented.rs", Some("README_env.md")), 0);
    // Without the README the same reference fires.
    assert_eq!(run_cli("env_documented.rs", None), 1);
}

#[test]
fn tcp_socket_panic_fires() {
    let vs = lint_fixture("tcp_socket_panic_fires.rs", None);
    assert_eq!(vs.len(), 3, "{vs:?}");
    assert!(kinds(&vs).iter().all(|k| *k == Lint::ForbiddenPanic), "{vs:?}");
    assert_eq!(run_cli("tcp_socket_panic_fires.rs", None), 1);
}

#[test]
fn tcp_socket_allowed_is_clean_with_documented_env() {
    let vs = lint_fixture("tcp_socket_allowed.rs", Some("README_tcp_env.md"));
    assert!(vs.is_empty(), "{vs:?}");
    assert_eq!(run_cli("tcp_socket_allowed.rs", Some("README_tcp_env.md")), 0);
}

#[test]
fn tcp_env_knobs_require_readme_rows() {
    // The same fixture without the README rows: every SDDN_TCP_* knob
    // fires exactly once — the contract that keeps the real transport's
    // tuning variables documented.
    let vs = lint_fixture("tcp_socket_allowed.rs", None);
    assert_eq!(vs.len(), 3, "{vs:?}");
    assert!(kinds(&vs).iter().all(|k| *k == Lint::UndocumentedEnv), "{vs:?}");
    assert_eq!(run_cli("tcp_socket_allowed.rs", None), 1);
}

#[test]
fn cli_rejects_bad_usage() {
    let code = Command::new(env!("CARGO_BIN_EXE_sddn-lint"))
        .arg("--no-such-flag")
        .status()
        .unwrap()
        .code()
        .unwrap();
    assert_eq!(code, 2);
}

#[test]
fn repo_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let tree = lint_repo(&root).unwrap();
    assert!(tree.files > 20, "expected to scan the full rust/src tree, saw {}", tree.files);
    let rendered: Vec<String> = tree.violations.iter().map(|v| v.to_string()).collect();
    assert!(
        tree.violations.is_empty(),
        "repo lint violations:\n{}",
        rendered.join("\n")
    );
}
