"""L1 Pallas kernel: fused logistic margins/sigmoid/gradient assembly.

The compute hot-spot of the logistic consensus experiments: for every
node, stream the (m, p) feature block through VMEM-sized tiles, compute
margins ``z = B theta``, the sigmoid residual ``delta = sigma(z) - a``,
the Gauss-Newton weights ``d = sigma(1-sigma)``, and accumulate the
data-term gradient ``B^T delta`` in a (p,)-resident accumulator.

TPU mapping (DESIGN.md *Hardware-Adaptation*): the grid walks (node,
sample-tile); each step does one (tile_m x p) @ (p,) MXU pass plus one
(p x tile_m) @ (tile_m,) accumulation, with the (p,) accumulator pinned
in VMEM across the inner grid dimension. ``interpret=True`` everywhere -
the CPU PJRT plugin cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(b_ref, a_ref, theta_ref, grad_ref, dw_ref):
    """One (node, sample-tile) grid step."""
    b = b_ref[0]          # (tile_m, p)
    a = a_ref[0]          # (tile_m,)
    theta = theta_ref[0]  # (p,)
    z = b @ theta
    s = jax.nn.sigmoid(z)
    delta = s - a
    dw_ref[0, :] = s * (1.0 - s)

    # Zero the accumulator on the first sample-tile of each node, then
    # accumulate B^T delta across tiles (output index map is constant in
    # the tile dimension, so the block stays resident).
    @pl.when(pl.program_id(1) == 0)
    def _init():
        grad_ref[0, :] = jnp.zeros_like(grad_ref[0, :])

    grad_ref[0, :] += b.T @ delta


def pick_tile_m(m: int, cap: int = 128) -> int:
    """Largest divisor of m that is <= cap. Coarse tiles amortize the
    per-grid-step overhead of interpret mode while still modelling a
    VMEM-bounded schedule (tile_m·p·8B per slab on a real TPU)."""
    best = 1
    for d in range(1, min(cap, m) + 1):
        if m % d == 0:
            best = d
    return best


@functools.partial(jax.jit, static_argnames=("tile_m",))
def logistic_grad_hess(b, a, theta, tile_m=None):
    """Pallas-fused version of ``ref.logistic_grad_hess_ref``.

    Shapes: b (n, m, p), a (n, m), theta (n, p) ->
    grad (n, p), dw (n, m).
    """
    n, m, p = b.shape
    if tile_m is None:
        tile_m = pick_tile_m(m)
    assert m % tile_m == 0, f"m={m} not divisible by tile_m={tile_m}"
    grid = (n, m // tile_m)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_m, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
            pl.BlockSpec((1, p), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, p), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), b.dtype),
            jax.ShapeDtypeStruct((n, m), b.dtype),
        ],
        interpret=True,
    )(b, a, theta)
