"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are asserted against in
``python/tests/test_kernels.py`` (and, transitively, what the rust
``NativeBackend`` mirrors in f64).
"""

import jax
import jax.numpy as jnp


def logistic_grad_hess_ref(b, a, theta):
    """Per-node logistic data-term gradient and Hessian weights.

    Args:
      b: (n, m, p) feature rows per node (zero rows = padding).
      a: (n, m) labels in {0, 1} (padding rows contribute nothing since
         their feature row is zero).
      theta: (n, p) current iterates.

    Returns:
      grad_data: (n, p) = B^T (sigma(B theta) - a) per node.
      dw:        (n, m) = sigma * (1 - sigma) per example.
    """
    z = jnp.einsum("nmp,np->nm", b, theta)
    s = jax.nn.sigmoid(z)
    delta = s - a
    grad = jnp.einsum("nmp,nm->np", b, delta)
    dw = s * (1.0 - s)
    return grad, dw


def quad_apply_ref(p_mat, z):
    """Batched quadratic Hessian application: (n,p,p),(n,p) -> (n,p) = 2 P z."""
    return 2.0 * jnp.einsum("nij,nj->ni", p_mat, z)
