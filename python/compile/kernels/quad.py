"""L1 Pallas kernel: batched quadratic Hessian application ``2 P z``.

Per node the (p, p) sufficient-statistic matrix multiplies the (p,)
direction — the Eq.-9 ``b`` vectors for every quadratic benchmark
(synthetic regression, London Schools, RL). The grid walks nodes; for
large p the matrix is streamed through VMEM in row tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, z_ref, out_ref):
    # (tile_n, p, p) @ (tile_n, p) -> (tile_n, p), batched over the tile.
    out_ref[...] = 2.0 * jnp.einsum("npq,nq->np", p_ref[...], z_ref[...])


def pick_tile_n(n: int, cap: int = 32) -> int:
    """Largest divisor of n that is <= cap. Coarser node tiles amortize the
    per-grid-step overhead of the interpret-mode while loop (a real-TPU
    build would instead size tiles to the VMEM budget: tile_n·(p²+2p)·8B)."""
    best = 1
    for d in range(1, min(cap, n) + 1):
        if n % d == 0:
            best = d
    return best


@functools.partial(jax.jit, static_argnames=("tile_n",))
def quad_apply(p_mat, z, tile_n=None):
    """Pallas version of ``ref.quad_apply_ref``: (n,p,p),(n,p) -> (n,p)."""
    n, p, _ = p_mat.shape
    if tile_n is None:
        tile_n = pick_tile_n(n)
    assert n % tile_n == 0
    return pl.pallas_call(
        _kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, p, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_n, p), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p), p_mat.dtype),
        interpret=True,
    )(p_mat, z)


def _unused():  # pragma: no cover - keeps jnp import referenced
    return jnp.zeros(())
