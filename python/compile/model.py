"""L2 JAX model: batched per-node local computations for the dual Newton
methods (Eq. 6 primal recovery + Eq. 9 local Hessian application).

These are the functions AOT-lowered by ``aot.py`` into
``artifacts/*.hlo.txt`` and executed from rust via PJRT. They call the L1
Pallas kernels (``kernels.logistic``, ``kernels.quad``); everything is
pure HLO ops (no LAPACK custom-calls): the SPD solves are matrix-free CG
with fixed trip counts, which XLA fuses into a tight scan body.

All computations run in f64 to match the rust native oracle.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import logistic as klog
from compile.kernels import quad as kquad

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Batched matrix-free conjugate gradients (the SPD p x p solves).
# ---------------------------------------------------------------------------

def _batched_cg(matvec, rhs, iters):
    """Solve A x = rhs per node with fixed-iteration CG.

    matvec: (n, p) -> (n, p); rhs: (n, p). Pure lax.fori_loop, no early
    exit (AOT-friendly fixed trip count). The tiny ridge in the rho
    denominators guards padded/converged nodes.
    """
    x0 = jnp.zeros_like(rhs)

    def body(_, state):
        x, r, q, rho = state
        aq = matvec(q)
        denom = jnp.sum(q * aq, axis=1, keepdims=True)
        alpha = rho / (denom + 1e-300)
        x = x + alpha * q
        r = r - alpha * aq
        rho_new = jnp.sum(r * r, axis=1, keepdims=True)
        beta = rho_new / (rho + 1e-300)
        q = r + beta * q
        return x, r, q, rho_new

    r0 = rhs
    rho0 = jnp.sum(r0 * r0, axis=1, keepdims=True)
    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x0, r0, r0, rho0))
    return x


# ---------------------------------------------------------------------------
# Quadratic problems (linear regression / London Schools / RL).
# ---------------------------------------------------------------------------

def quad_recover(p_mat, c, v, cg_iters):
    """Primal recovery for quadratic locals: y_i = P_i^{-1}(c_i - v_i/2).

    p_mat: (n, p, p) SPD; c: (n, p); v: (n, p) Lagrangian rows (L Lambda).
    """
    rhs = c - 0.5 * v
    matvec = lambda u: jnp.einsum("nij,nj->ni", p_mat, u)
    return _batched_cg(matvec, rhs, cg_iters)


def quad_recover_pre(p_inv, c, v):
    """Primal recovery with a precomputed inverse: y_i = P_i^{-1}(c_i - v_i/2).

    The coordinator computes P_i^{-1} once at startup (P_i is constant for
    quadratic problems), turning every request-path recovery into a single
    batched matmul instead of a CG solve (see EXPERIMENTS.md §Perf).
    """
    rhs = c - 0.5 * v
    return jnp.einsum("nij,nj->ni", p_inv, rhs)


def quad_hess_apply(p_mat, z):
    """b_i = (2 P_i) z_i via the Pallas kernel."""
    return kquad.quad_apply(p_mat, z)


# ---------------------------------------------------------------------------
# Logistic problems (MNIST-like / fMRI-like).
# ---------------------------------------------------------------------------

def _reg_grad(theta, reg_scale, reg, alpha):
    """Gradient of the regularizer. reg_scale = mu_i * m_i per node (n, 1)."""
    if reg == "l2":
        return 2.0 * reg_scale * theta
    # smooth-L1 (Eq. 73): d/dx = tanh(alpha x / 2)
    return reg_scale * jnp.tanh(alpha * theta / 2.0)


def _reg_hess_diag(theta, reg_scale, reg, alpha):
    if reg == "l2":
        return 2.0 * reg_scale * jnp.ones_like(theta)
    s = jax.nn.sigmoid(alpha * theta)
    return 2.0 * alpha * reg_scale * s * (1.0 - s)


def logreg_hess_apply(b, a, theta, z, reg_scale, reg="l2", alpha=8.0):
    """b_i = nabla^2 f_i(theta_i) z_i, matrix-free:
    B^T (d * (B z)) + reg''(theta) * z. Uses the Pallas kernel for the
    sigmoid weights d.
    """
    _, dw = klog.logistic_grad_hess(b, a, theta)
    bz = jnp.einsum("nmp,np->nm", b, z)
    data = jnp.einsum("nmp,nm->np", b, dw * bz)
    return data + _reg_hess_diag(theta, reg_scale, reg, alpha) * z


def logreg_recover(
    b, a, v, reg_scale, theta0=None, reg="l2", alpha=8.0, newton_iters=20,
    cg_iters=40,
):
    """Primal recovery for logistic locals (inner Newton of Eq. 52-54).

    b: (n, m, p); a: (n, m); v: (n, p); reg_scale: (n, 1) = mu_i m_i;
    theta0: (n, p) warm start (the coordinator passes the previous primal
    iterate — successive dual iterates are close, so a handful of Newton
    steps suffice; see EXPERIMENTS.md §Perf).
    Fixed newton_iters damped-by-CG steps, each assembling the gradient
    with the Pallas kernel and solving the Newton system matrix-free.
    """

    def newton_body(_, theta):
        grad_data, dw = klog.logistic_grad_hess(b, a, theta)
        grad = grad_data + _reg_grad(theta, reg_scale, reg, alpha) + v
        hdiag = _reg_hess_diag(theta, reg_scale, reg, alpha)

        def hvp(u):
            bu = jnp.einsum("nmp,np->nm", b, u)
            return jnp.einsum("nmp,nm->np", b, dw * bu) + hdiag * u + 1e-10 * u

        step = _batched_cg(hvp, grad, cg_iters)
        return theta - step

    if theta0 is None:
        theta0 = jnp.zeros_like(v)
    return jax.lax.fori_loop(0, newton_iters, newton_body, theta0)


# ---------------------------------------------------------------------------
# jit wrappers with static configuration (what aot.py lowers).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cg_iters",))
def quad_recover_jit(p_mat, c, v, cg_iters=0):
    return (quad_recover(p_mat, c, v, cg_iters),)


@jax.jit
def quad_recover_pre_jit(p_inv, c, v):
    return (quad_recover_pre(p_inv, c, v),)


@jax.jit
def quad_hess_apply_jit(p_mat, z):
    return (quad_hess_apply(p_mat, z),)


@functools.partial(
    jax.jit, static_argnames=("reg", "alpha", "newton_iters", "cg_iters")
)
def logreg_recover_jit(
    b, a, v, reg_scale, reg="l2", alpha=8.0, newton_iters=20, cg_iters=40
):
    return (
        logreg_recover(
            b, a, v, reg_scale, reg=reg, alpha=alpha,
            newton_iters=newton_iters, cg_iters=cg_iters,
        ),
    )


@functools.partial(
    jax.jit, static_argnames=("reg", "alpha", "newton_iters", "cg_iters")
)
def logreg_recover_warm_jit(
    b, a, v, reg_scale, theta0, reg="l2", alpha=8.0, newton_iters=6,
    cg_iters=40,
):
    return (
        logreg_recover(
            b, a, v, reg_scale, theta0=theta0, reg=reg, alpha=alpha,
            newton_iters=newton_iters, cg_iters=cg_iters,
        ),
    )


@functools.partial(jax.jit, static_argnames=("reg", "alpha"))
def logreg_hess_apply_jit(b, a, theta, z, reg_scale, reg="l2", alpha=8.0):
    return (logreg_hess_apply(b, a, theta, z, reg_scale, reg=reg, alpha=alpha),)
