"""AOT lowering: JAX model -> HLO text artifacts for the rust runtime.

HLO *text* is the interchange format, not the serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the published
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--specs default]

Writes one ``<name>.hlo.txt`` per (function, shape) pair plus a
``manifest.json`` the rust ``PjrtBackend`` uses to pick artifacts.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

jax.config.update("jax_enable_x64", True)

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def s(shape):
    return jax.ShapeDtypeStruct(shape, F64)


def lower_quad(n, p, cg_iters):
    """Artifacts for quadratic problems at (n, p)."""
    rec = jax.jit(
        lambda P, c, v: model.quad_recover_jit(P, c, v, cg_iters=cg_iters)
    ).lower(s((n, p, p)), s((n, p)), s((n, p)))
    rec_pre = jax.jit(model.quad_recover_pre_jit).lower(
        s((n, p, p)), s((n, p)), s((n, p))
    )
    hess = jax.jit(model.quad_hess_apply_jit).lower(s((n, p, p)), s((n, p)))
    return {
        f"quad_recover_n{n}_p{p}": (
            to_hlo_text(rec),
            {"kind": "quad_recover", "n": n, "p": p, "cg_iters": cg_iters},
        ),
        f"quad_recover_pre_n{n}_p{p}": (
            to_hlo_text(rec_pre),
            {"kind": "quad_recover_pre", "n": n, "p": p},
        ),
        f"quad_hess_n{n}_p{p}": (
            to_hlo_text(hess),
            {"kind": "quad_hess", "n": n, "p": p},
        ),
    }


def lower_logreg(n, p, m, reg, alpha, newton_iters, cg_iters):
    """Artifacts for logistic problems at (n, p, m padded examples).

    The recover artifact is warm-started: input θ₀ is the coordinator's
    previous primal iterate, so the Newton count stays small.
    """
    tag = f"n{n}_p{p}_m{m}_{reg}"
    rec = jax.jit(
        lambda b, a, v, rs, t0: model.logreg_recover_warm_jit(
            b, a, v, rs, t0, reg=reg, alpha=alpha,
            newton_iters=newton_iters, cg_iters=cg_iters,
        )
    ).lower(s((n, m, p)), s((n, m)), s((n, p)), s((n, 1)), s((n, p)))
    hess = jax.jit(
        lambda b, a, th, z, rs: model.logreg_hess_apply_jit(
            b, a, th, z, rs, reg=reg, alpha=alpha
        )
    ).lower(s((n, m, p)), s((n, m)), s((n, p)), s((n, p)), s((n, 1)))
    meta = {
        "n": n, "p": p, "m": m, "reg": reg, "alpha": alpha,
        "newton_iters": newton_iters, "cg_iters": cg_iters,
    }
    return {
        f"logreg_recover_{tag}": (to_hlo_text(rec), {"kind": "logreg_recover", **meta}),
        f"logreg_hess_{tag}": (to_hlo_text(hess), {"kind": "logreg_hess", **meta}),
    }


def default_specs():
    """The artifact set covering DESIGN.md's experiment index."""
    out = {}
    # Fig 1(a,b): synthetic regression, 100 nodes, p = 80.
    out.update(lower_quad(100, 80, cg_iters=80))
    # Fig 3(a,b) + 2(c,d): London Schools, 50 nodes, p = 27.
    out.update(lower_quad(50, 27, cg_iters=27))
    # Fig 3(c,d): RL, 20 nodes, p = 6.
    out.update(lower_quad(20, 6, cg_iters=6))
    # Small smoke shape used by tests/examples.
    out.update(lower_quad(8, 5, cg_iters=5))
    # Fig 1(c-f): MNIST-like, 10 nodes, p = 150, 200 examples/node.
    # Warm-started recovers keep the Newton budget small (§Perf).
    out.update(lower_logreg(10, 150, 200, "l2", 8.0, 6, 32))
    out.update(lower_logreg(10, 150, 200, "sl1", 8.0, 6, 32))
    # Fig 2(a,b): fMRI-like, 8 nodes, p = 512, 30 examples/node.
    out.update(lower_logreg(8, 512, 32, "sl1", 8.0, 8, 48))
    # Small logistic smoke shape.
    out.update(lower_logreg(6, 8, 16, "l2", 8.0, 8, 16))
    return out


def smoke_specs():
    out = {}
    out.update(lower_quad(8, 5, cg_iters=5))
    out.update(lower_logreg(6, 8, 16, "l2", 8.0, 8, 16))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--specs", default="default", choices=["default", "smoke"])
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = default_specs() if args.specs == "default" else smoke_specs()
    manifest = {}
    for name, (text, meta) in artifacts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {**meta, "file": f"{name}.hlo.txt", "bytes": len(text)}
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
