"""L2 model correctness: primal recovery + Hessian application."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_enable_x64", True)


def spd_batch(key, n, p):
    b = jax.random.normal(key, (n, p, p), dtype=jnp.float64)
    return jnp.einsum("nij,nkj->nik", b, b) + p * jnp.eye(p)[None]


def test_quad_recover_solves_stationarity():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, p = 5, 7
    P = spd_batch(k1, n, p)
    c = jax.random.normal(k2, (n, p), dtype=jnp.float64)
    v = jax.random.normal(k3, (n, p), dtype=jnp.float64)
    (y,) = model.quad_recover_jit(P, c, v, cg_iters=2 * p)
    # grad f + v = 2 P y - 2 c + v = 0.
    resid = 2 * jnp.einsum("nij,nj->ni", P, y) - 2 * c + v
    np.testing.assert_allclose(np.asarray(resid), 0.0, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 4), p=st.integers(2, 12), seed=st.integers(0, 10**6))
def test_quad_hess_apply_matches_dense(n, p, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    P = spd_batch(k1, n, p)
    z = jax.random.normal(k2, (n, p), dtype=jnp.float64)
    (out,) = model.quad_hess_apply_jit(P, z)
    expect = 2 * jnp.einsum("nij,nj->ni", P, z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-10)


def _logreg_value_grad(b, a, theta, v, reg_scale, reg, alpha):
    z = jnp.einsum("nmp,np->nm", b, theta)
    loss = jnp.sum(-a * z + jnp.logaddexp(0.0, z), axis=1)
    if reg == "l2":
        r = reg_scale[:, 0] * jnp.sum(theta**2, axis=1)
    else:
        sab = (
            jnp.logaddexp(0.0, -alpha * theta) + jnp.logaddexp(0.0, alpha * theta)
        ) / alpha
        r = reg_scale[:, 0] * jnp.sum(sab, axis=1)
    return jnp.sum(loss + r + jnp.sum(theta * v, axis=1))


def test_logreg_recover_stationarity_l2():
    key = jax.random.PRNGKey(3)
    kb, ka, kv = jax.random.split(key, 3)
    n, m, p = 4, 16, 6
    b = jax.random.normal(kb, (n, m, p), dtype=jnp.float64)
    a = (jax.random.uniform(ka, (n, m)) > 0.5).astype(jnp.float64)
    v = 0.5 * jax.random.normal(kv, (n, p), dtype=jnp.float64)
    rs = jnp.full((n, 1), 0.05 * m, dtype=jnp.float64)
    (theta,) = model.logreg_recover_jit(
        b, a, v, rs, reg="l2", newton_iters=25, cg_iters=2 * p
    )
    grad = jax.grad(
        lambda t: _logreg_value_grad(b, a, t, v, rs, "l2", 8.0)
    )(theta)
    np.testing.assert_allclose(np.asarray(grad), 0.0, atol=1e-7)


def test_logreg_recover_stationarity_smooth_l1():
    key = jax.random.PRNGKey(4)
    kb, ka, kv = jax.random.split(key, 3)
    n, m, p = 3, 16, 5
    b = jax.random.normal(kb, (n, m, p), dtype=jnp.float64)
    a = (jax.random.uniform(ka, (n, m)) > 0.5).astype(jnp.float64)
    v = 0.3 * jax.random.normal(kv, (n, p), dtype=jnp.float64)
    rs = jnp.full((n, 1), 0.05 * m, dtype=jnp.float64)
    (theta,) = model.logreg_recover_jit(
        b, a, v, rs, reg="sl1", alpha=8.0, newton_iters=30, cg_iters=2 * p
    )
    grad = jax.grad(
        lambda t: _logreg_value_grad(b, a, t, v, rs, "sl1", 8.0)
    )(theta)
    np.testing.assert_allclose(np.asarray(grad), 0.0, atol=1e-6)


def test_logreg_hess_apply_matches_autodiff():
    key = jax.random.PRNGKey(5)
    kb, ka, kt, kz = jax.random.split(key, 4)
    n, m, p = 3, 8, 4
    b = jax.random.normal(kb, (n, m, p), dtype=jnp.float64)
    a = (jax.random.uniform(ka, (n, m)) > 0.5).astype(jnp.float64)
    theta = jax.random.normal(kt, (n, p), dtype=jnp.float64)
    z = jax.random.normal(kz, (n, p), dtype=jnp.float64)
    rs = jnp.full((n, 1), 0.1 * m, dtype=jnp.float64)
    (out,) = model.logreg_hess_apply_jit(b, a, theta, z, rs, reg="l2")

    def f_sum(t):
        zz = jnp.einsum("nmp,np->nm", b, t)
        loss = jnp.sum(-a * zz + jnp.logaddexp(0.0, zz))
        return loss + jnp.sum(rs[:, 0] * jnp.sum(t**2, axis=1))

    hvp = jax.jvp(jax.grad(f_sum), (theta,), (z,))[1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(hvp), atol=1e-8)
