"""AOT path: lowering produces parseable HLO text with the right
entry signature, and the manifest describes it accurately."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_enable_x64", True)


def test_quad_lowering_has_f64_signature():
    arts = aot.lower_quad(3, 4, cg_iters=4)
    name = "quad_recover_n3_p4"
    text, meta = arts[name]
    assert meta["kind"] == "quad_recover"
    assert "f64[3,4,4]" in text, "P input shape missing from HLO"
    assert "f64[3,4]" in text
    assert text.startswith("HloModule")


def test_logreg_lowering_both_regs():
    for reg in ("l2", "sl1"):
        arts = aot.lower_logreg(2, 3, 8, reg, 8.0, 2, 4)
        rec_name = f"logreg_recover_n2_p3_m8_{reg}"
        text, meta = arts[rec_name]
        assert meta["reg"] == reg
        assert "f64[2,8,3]" in text


def test_smoke_specs_write_manifest(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "arts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--specs", "smoke"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest) == 5  # quad recover + recover_pre + hess, logreg recover + hess
    for name, meta in manifest.items():
        f = out / meta["file"]
        assert f.exists(), name
        assert f.stat().st_size == meta["bytes"]


def test_lowered_function_matches_eager():
    """The exact function lowered for artifacts equals eager execution."""
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    n, p = 3, 4
    b = jax.random.normal(k1, (n, p, p), dtype=jnp.float64)
    P = jnp.einsum("nij,nkj->nik", b, b) + p * jnp.eye(p)[None]
    c = jax.random.normal(k2, (n, p), dtype=jnp.float64)
    v = jax.random.normal(k3, (n, p), dtype=jnp.float64)
    (y,) = model.quad_recover_jit(P, c, v, cg_iters=2 * p)
    resid = 2 * jnp.einsum("nij,nj->ni", P, y) - 2 * c + v
    assert float(jnp.abs(resid).max()) < 1e-8
