"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logistic as klog
from compile.kernels import quad as kquad
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=dtype)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5),
    m_tiles=st.integers(1, 4),
    p=st.integers(1, 24),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_logistic_kernel_matches_ref(n, m_tiles, p, dtype, seed):
    m = 8 * m_tiles
    key = jax.random.PRNGKey(seed)
    kb, ka, kt = jax.random.split(key, 3)
    b = rand(kb, (n, m, p), dtype)
    a = (jax.random.uniform(ka, (n, m)) > 0.5).astype(dtype)
    theta = rand(kt, (n, p), dtype)
    g_ref, dw_ref = ref.logistic_grad_hess_ref(b, a, theta)
    g_pl, dw_pl = klog.logistic_grad_hess(b, a, theta, tile_m=klog.pick_tile_m(m))
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(g_pl), np.asarray(g_ref), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(dw_pl), np.asarray(dw_ref), atol=tol, rtol=tol)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 6),
    p=st.integers(1, 32),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quad_kernel_matches_ref(n, p, dtype, seed):
    key = jax.random.PRNGKey(seed)
    kp, kz = jax.random.split(key)
    p_mat = rand(kp, (n, p, p), dtype)
    z = rand(kz, (n, p), dtype)
    out_ref = ref.quad_apply_ref(p_mat, z)
    out_pl = kquad.quad_apply(p_mat, z)
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref), atol=tol, rtol=tol)


def test_logistic_kernel_padding_rows_are_inert():
    """Zero feature rows (padding) must not change grad regardless of label."""
    key = jax.random.PRNGKey(0)
    b = rand(key, (2, 8, 4), jnp.float64)
    b = b.at[:, 6:, :].set(0.0)
    a1 = jnp.zeros((2, 8))
    a2 = a1.at[:, 6:].set(1.0)
    theta = rand(jax.random.PRNGKey(1), (2, 4), jnp.float64)
    g1, _ = klog.logistic_grad_hess(b, a1, theta)
    g2, _ = klog.logistic_grad_hess(b, a2, theta)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-12)


def test_tile_sweep_consistency():
    """Different tile sizes must give identical results."""
    key = jax.random.PRNGKey(7)
    b = rand(key, (3, 64, 10), jnp.float64)
    a = (jax.random.uniform(jax.random.PRNGKey(8), (3, 64)) > 0.5).astype(jnp.float64)
    theta = rand(jax.random.PRNGKey(9), (3, 10), jnp.float64)
    outs = [
        klog.logistic_grad_hess(b, a, theta, tile_m=t) for t in (8, 16, 32, 64)
    ]
    for g, dw in outs[1:]:
        np.testing.assert_allclose(np.asarray(g), np.asarray(outs[0][0]), atol=1e-12)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(outs[0][1]), atol=1e-12)


def test_pick_tile_m():
    assert klog.pick_tile_m(256) == 128
    assert klog.pick_tile_m(200) == 100
    assert klog.pick_tile_m(30) == 30
    assert klog.pick_tile_m(7) == 7
    assert klog.pick_tile_m(127) == 127
    assert klog.pick_tile_m(509) == 1  # prime > cap


def test_pick_tile_n():
    assert kquad.pick_tile_n(100) == 25
    assert kquad.pick_tile_n(8) == 8
    assert kquad.pick_tile_n(50) == 25
    assert kquad.pick_tile_n(37) == 1  # prime > cap


@pytest.mark.parametrize("extreme", [60.0, -60.0])
def test_logistic_kernel_extreme_margins(extreme):
    """Saturated sigmoids must stay finite (no NaN/Inf)."""
    b = jnp.ones((1, 8, 2), jnp.float64)
    a = jnp.zeros((1, 8))
    theta = jnp.full((1, 2), extreme)
    g, dw = klog.logistic_grad_hess(b, a, theta)
    assert np.isfinite(np.asarray(g)).all()
    assert np.isfinite(np.asarray(dw)).all()
